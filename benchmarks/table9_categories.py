"""Paper Tables 9+10: anticlustering with a categorical constraint --
quality/time (T9) and diversity-balance stats (T10) vs the exchange heuristic
and category-balanced random.  Categories derived by k-means as in the paper
(Section 5.4); the MILP/Gurobi baseline is replaced by the exact-small
optimality check in tests/."""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.anticluster import anticluster
from repro.core import diversity_stats, objective_centroid
from repro.core.baselines import fast_anticlustering, random_partition
from repro.data import synthetic

from benchmarks.common import dev_pct, kmeans_labels, row

SETTINGS = [("abalone", 3, (4, 10)), ("facebook", 3, (7, 18)),
            ("frogs", 4, (8, 16)), ("electric", 3, (10, 30)),
            ("pulsar", 2, (18, 35))]


def run(full: bool = False):
    print("# table9/10: dataset,G,K,ofv_aba,dev_PR5,dev_rand,cpu_aba_s,"
          "cpu_PR5_s,sd_aba,sd_dev_PR5,sd_dev_rand")
    for name, g, kvals in SETTINGS:
        x = synthetic.load(name, max_n=None if full else 10_000)
        cats = kmeans_labels(x[:, :4], g, seed=0)
        xj = jnp.asarray(x)
        for k in kvals:
            t0 = time.time()
            la = np.asarray(anticluster(
                xj, k=k, plan=None, categories=jnp.asarray(cats),
                n_categories=g, stats=False).labels)
            t_aba = time.time() - t0
            oa = float(objective_centroid(xj, jnp.asarray(la), k))
            sd_a, _ = (float(v) for v in diversity_stats(xj, jnp.asarray(la), k))
            t0 = time.time()
            lb = fast_anticlustering(x, k, n_partners=5, seed=0,
                                     categories=cats)
            t_ex = time.time() - t0
            ob = float(objective_centroid(xj, jnp.asarray(lb), k))
            sd_b, _ = (float(v) for v in diversity_stats(xj, jnp.asarray(lb), k))
            lr = random_partition(len(x), k, seed=0, categories=cats)
            orr = float(objective_centroid(xj, jnp.asarray(lr), k))
            sd_r, _ = (float(v) for v in diversity_stats(xj, jnp.asarray(lr), k))
            print(f"table9,{name},{g},{k},{oa:.2f},{dev_pct(oa, ob):+.4f},"
                  f"{dev_pct(oa, orr):+.4f},{t_aba:.3f},{t_ex:.3f},"
                  f"{sd_a:.3f},{dev_pct(sd_a, sd_b):+.1f},"
                  f"{dev_pct(sd_a, sd_r):+.1f}", flush=True)
            row(f"table9/{name}/k{k}", t_aba,
                f"dev_PR5={dev_pct(oa, ob):+.4f}%;sd_dev={dev_pct(sd_a, sd_b):+.0f}%")


if __name__ == "__main__":
    run()
