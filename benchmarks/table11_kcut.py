"""Paper Table 11: balanced k-cut on tabular data -- ABA vs the greedy
refinement baseline (METIS proxy, 30-random-neighbour information budget, see
DESIGN.md) vs random.  Reports W(C) (equivalently cut cost), runtimes, and
the min/max anticluster size ratio."""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.anticluster import anticluster
from repro.core import objective_pairwise
from repro.core.baselines import greedy_kcut, random_partition
from repro.data import synthetic

from benchmarks.common import dev_pct, row

SETTINGS = [("abalone", (4, 10)), ("facebook", (7, 18)), ("frogs", (8, 16)),
            ("electric", (10, 30)), ("creditcard", (2, 6))]


def run(full: bool = False):
    print("# table11: dataset,K,W_aba,dev_kcut,dev_rand,cpu_aba_s,cpu_kcut_s,"
          "ratio_aba,ratio_kcut")
    for name, kvals in SETTINGS:
        x = synthetic.load(name, max_n=None if full else 10_000)
        xj = jnp.asarray(x)
        n = len(x)
        for k in kvals:
            t0 = time.time()
            la = np.asarray(anticluster(xj, k=k, plan=None, stats=False).labels)
            t_aba = time.time() - t0
            wa = float(objective_pairwise(xj, jnp.asarray(la), k))
            t0 = time.time()
            lm = greedy_kcut(x, k, seed=0)
            t_m = time.time() - t0
            wm = float(objective_pairwise(xj, jnp.asarray(lm), k))
            lr = random_partition(n, k, seed=0)
            wr = float(objective_pairwise(xj, jnp.asarray(lr), k))

            def ratio(lab):
                c = np.bincount(lab, minlength=k)
                return (1.0 if c.max() - c.min() <= 1
                        else c.min() / max(c.max(), 1))

            print(f"table11,{name},{k},{wa:.1f},{dev_pct(wa, wm):+.4f},"
                  f"{dev_pct(wa, wr):+.4f},{t_aba:.3f},{t_m:.3f},"
                  f"{ratio(la):.3f},{ratio(lm):.3f}", flush=True)
            row(f"table11/{name}/k{k}", t_aba,
                f"dev_kcut={dev_pct(wa, wm):+.4f}%;dev_rand={dev_pct(wa, wr):+.4f}%")


if __name__ == "__main__":
    run()
