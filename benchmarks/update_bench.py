"""Incremental-update trajectory: ``engine.update`` vs full ``repartition``.

The delta subsystem's whole value proposition is measurable: absorbing a
small arrival/departure delta into a live partition must be *faster* than
re-solving the post-delta rows from warm state, while staying within a hair
of its objective.  This benchmark sweeps delta fractions on a live
:class:`~repro.anticluster.AnticlusterEngine` session and, per fraction,
measures

* ``update/...``      -- warm ``engine.update`` wall time (the delta path;
  asserted to actually take it, ``result.updated``),
* ``repart/...``      -- warm full ``repartition`` of the same post-delta
  rows (the baseline the delta path must beat), and
* the objective ratio between the two (the local patch is allowed to drift,
  but only marginally).

Every run emits ``BENCH_update.json`` (``benchmarks.common.BENCH_SCHEMA``);
CI runs ``--smoke``, gates wall times against the checked-in baseline via
``benchmarks.check_regression``, and this module *additionally* self-gates
the acceptance contract in smoke mode: at delta fractions <= 10% the update
path must beat the full repartition wall clock AND land within 1% of its
objective, else exit non-zero.  ``--full`` sweeps larger sessions (nightly).
"""

from __future__ import annotations

import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.anticluster import AnticlusterEngine
from repro.core import objective_centroid
from repro.data import synthetic

from benchmarks.common import BenchRecorder, row

# smoke acceptance contract: delta fractions at or below this must beat the
# full warm repartition on wall time and stay within OBJ_TOL of its ofv
GATE_FRACTION = 0.10
OBJ_TOL = 0.01


def _timed_update(eng, x, state, added, removed):
    t0 = time.time()
    res, new_x, new_state = eng.update(x, state, added=added,
                                       removed=removed)
    np.asarray(res.labels)  # sync
    return res, new_x, new_state, time.time() - t0


def run(full: bool = False, smoke: bool = False,
        json_path: str = "BENCH_update.json") -> int:
    rec = BenchRecorder()
    # (n, d, k, delta fractions)
    if smoke:
        shapes = [(4096, 8, 16, (0.02, 0.05, 0.10))]
    elif full:
        shapes = [(65536, 16, 64, (0.01, 0.02, 0.05, 0.10, 0.20)),
                  (262144, 16, 256, (0.01, 0.05, 0.10))]
    else:
        shapes = [(16384, 8, 32, (0.01, 0.05, 0.10))]
    print("# update_bench: n,d,k,frac,update_s,repart_s,speedup,"
          "obj_ratio,updated")
    failures = []

    for n, d, k, fracs in shapes:
        rng = np.random.default_rng(0)
        x0 = jnp.asarray(synthetic.make("lowrank", n, d, seed=0))
        # threshold high enough that every swept fraction takes the delta
        # path -- the point is to measure it, not the fallback
        eng = AnticlusterEngine(k=k, stats=False, update_threshold=0.5)
        _, state = eng.partition(x0)

        for frac in fracs:
            m = max(1, int(round(frac * n)))
            added = jnp.asarray(
                synthetic.make("lowrank", m, d, seed=1 + m))
            removed = np.sort(rng.choice(n, size=m, replace=False))

            # fresh live session per fraction (x stays (n, d): remove m,
            # add m), warmed so wall times are compile-free on both paths
            _, st_warm = eng.partition(x0)
            _timed_update(eng, x0, st_warm, added, removed)  # warm trace
            _, st = eng.partition(x0)
            res_u, new_x, _, t_u = _timed_update(eng, x0, st, added,
                                                 removed)
            if not res_u.updated:
                failures.append(f"n={n} frac={frac}: fell back to a full "
                                "repartition (delta path not exercised)")
            o_u = float(objective_centroid(new_x, res_u.labels, k))

            # the baseline: warm full repartition of the same rows (state
            # from a prior same-shape solve, exactly the live alternative)
            _, st_b = eng.partition(new_x)
            t0 = time.time()
            res_r, _ = eng.repartition(new_x, st_b)
            np.asarray(res_r.labels)
            t_r = time.time() - t0
            o_r = float(objective_centroid(new_x, res_r.labels, k))

            ratio = o_u / o_r if o_r else float("nan")
            tag = f"n{n}_k{k}_f{int(frac * 100):02d}"
            rec.add(f"update/delta/{tag}", f"{n}x{d}x{k}", t_u, o_u)
            rec.add(f"update/repart/{tag}", f"{n}x{d}x{k}", t_r, o_r)
            print(f"update,{n},{d},{k},{frac:.2f},{t_u:.4f},{t_r:.4f},"
                  f"{t_r / max(t_u, 1e-9):.2f}x,{ratio:.5f},"
                  f"{res_u.updated}", flush=True)
            row(f"update/delta/{tag}", t_u,
                f"repart_s={t_r:.4f};obj_ratio={ratio:.5f}")

            if smoke and frac <= GATE_FRACTION:
                if t_u >= t_r:
                    failures.append(
                        f"n={n} frac={frac}: update {t_u:.4f}s did not "
                        f"beat repartition {t_r:.4f}s")
                if not ratio >= 1.0 - OBJ_TOL:
                    failures.append(
                        f"n={n} frac={frac}: objective ratio {ratio:.5f} "
                        f"below {1.0 - OBJ_TOL} of the full re-solve")

    rec.write(json_path)
    if failures:
        print("# update_bench acceptance FAILURES:")
        for f in failures:
            print(f"#   {f}")
        return 1
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="nightly sweep (larger sessions, more fractions)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape + acceptance gate (CI smoke step)")
    ap.add_argument("--json", default="BENCH_update.json",
                    help="trajectory output path (BENCH_SCHEMA rows)")
    args = ap.parse_args()
    sys.exit(run(full=args.full, smoke=args.smoke, json_path=args.json))
