"""Paper Tables 8/10 scale trajectory: streaming (chunked, matrix-free) ABA
vs the dense one-shot core across dataset sizes.

The paper's headline claim is million-object instances "within short running
times"; the streaming execution path (``chunk_size`` in ``AnticlusterSpec``,
``repro.core.aba.aba_stream`` underneath) is what carries that regime here:
peak live memory beyond the input is O(chunk*d + k*d) instead of the dense
core's O(n*d) permuted copy, and with the factored auction the (k, k) value
matrix is never materialized per round either.

Every run emits the machine-readable trajectory ``BENCH_scale.json``
(``benchmarks.common.BENCH_SCHEMA``); CI runs ``--smoke`` (downscaled
shapes, CPU-interpret-friendly), uploads the JSON as a workflow artifact
and gates on ``benchmarks.check_regression`` against the checked-in
baseline.  ``--full`` sweeps up to the paper's 10^6-class shapes (TPU or a
patient CPU).  The smallest shape always re-checks the parity contract:
``chunk_size >= n`` must reproduce the dense labels bit-for-bit.
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.anticluster import anticluster
from repro.core import objective_centroid
from repro.core.aba import aba_core, aba_stream
from repro.core.baselines import exchange_anticlustering
from repro.data import synthetic

from benchmarks.common import BenchRecorder, dev_pct, kmeans_labels, row


def _labels(x, k, chunk, max_k, solver, cats=None, stats=False):
    t0 = time.time()
    res = anticluster(x, k=k, plan="auto", max_k=max_k, chunk_size=chunk,
                      solver=solver, categories=cats, stats=stats)
    lab = np.asarray(res.labels)  # blocks; anticluster already synced labels
    return lab, time.time() - t0, res


def _temp_bytes(fn, *args, **kw) -> int:
    """Compiler-measured temp (scratch) bytes for a jitted call, -1 if the
    backend's memory analysis is unavailable (e.g. some CPU builds)."""
    return obs.memory_profile(fn, *args, **kw).temp_bytes


def run(full: bool = False, smoke: bool = False,
        json_path: str = "BENCH_scale.json"):
    rec = BenchRecorder()
    # (n, d, k, chunk, also_run_dense)
    if smoke:
        shapes = [(2048, 8, 16, 512, True),
                  (8192, 8, 32, 1024, True)]
    elif full:
        shapes = [(131072, 32, 256, 8192, True),
                  (1048576, 32, 4096, 8192, False),  # the Table-10 regime
                  (1048576, 32, 131072, 8192, False)]
    else:
        shapes = [(32768, 16, 64, 4096, True),
                  (131072, 16, 256, 8192, False)]
    max_k = 256
    print("# table10_scale: n,d,k,chunk,stream_s,dense_s,ofv_stream,dev%,"
          "gap")

    for i, (n, d, k, chunk, run_dense) in enumerate(shapes):
        x = jnp.asarray(synthetic.make("lowrank", n, d, seed=0))
        # warm (compile) then measure: trajectory rows are warm wall times
        _labels(x, k, chunk, max_k, "auction_fused")
        lab_s, t_s, _ = _labels(x, k, chunk, max_k, "auction_fused")
        o_s = float(objective_centroid(x, jnp.asarray(lab_s), k))
        counts = np.bincount(lab_s, minlength=k)
        assert counts.min() >= n // k and counts.max() <= -(-n // k), \
            "streaming path lost balance"
        rec.add(f"scale/stream/n{n}_k{k}", f"{n}x{d}x{k}", t_s, o_s)

        # the dual-bound optimality certificate rides a separate untimed
        # stats=True solve (stats stay out of the timed path by contract);
        # gap ~ 0 certifies the assignment step converged at these centroids
        _, _, res_c = _labels(x, k, chunk, max_k, "auction_fused",
                              stats=True)
        gap = float(res_c.gap)

        t_d, o_d = float("nan"), float("nan")
        if run_dense:
            _labels(x, k, None, max_k, "auction")
            lab_d, t_d, _ = _labels(x, k, None, max_k, "auction")
            o_d = float(objective_centroid(x, jnp.asarray(lab_d), k))
            rec.add(f"scale/dense/n{n}_k{k}", f"{n}x{d}x{k}", t_d, o_d)
        if i == 0:
            # the parity contract, re-proven at benchmark scale: one chunk
            # covering all rows reproduces the dense labels bit-for-bit
            lab_p, _, _ = _labels(x, k, n, max_k, "auction")
            lab_f, _, _ = _labels(x, k, None, max_k, "auction")
            assert np.array_equal(lab_p, lab_f), \
                "chunk_size >= n must be bit-identical to the dense path"
            print("# parity: chunk_size>=n == dense (bit-for-bit) OK")

        if k <= max_k:  # flat route: lower the exact calls being timed
            # the ROADMAP streaming receipt -- O(chunk*d + k*d) vs O(n*d)
            # live memory -- as trajectory rows.  memory_profile only
            # lowers+compiles (nothing executes), so wall_s is 0.0 by
            # construction and the gate's --min-seconds floor keeps these
            # rows permanently wall-neutral; the measured bytes ride in
            # ``objective`` and the extra columns.
            prof_s = obs.memory_profile(aba_stream, x, k, chunk,
                                        solver="auction")
            prof_d = obs.memory_profile(aba_core, x[None], k,
                                        solver="auction")
            peak = obs.peak_rss_bytes()
            for tag, prof in (("stream", prof_s), ("dense", prof_d)):
                rec.add(f"scale/memory/{tag}/n{n}_k{k}", f"{n}x{d}x{k}",
                        0.0, float(prof.temp_bytes),
                        extra={"argument_bytes": prof.argument_bytes,
                               "output_bytes": prof.output_bytes,
                               "peak_rss_bytes": peak})
            print(f"table10mem,{n},{d},{k},{chunk},"
                  f"temp_stream={prof_s.temp_bytes},"
                  f"temp_dense={prof_d.temp_bytes},peak_rss={peak}",
                  flush=True)

        dev = dev_pct(o_s, o_d) if run_dense else float("nan")
        print(f"table10,{n},{d},{k},{chunk},{t_s:.2f},{t_d:.2f},"
              f"{o_s:.1f},{dev:+.4f},{gap:.5f}", flush=True)
        row(f"scale/stream/n{n}_k{k}", t_s,
            f"dense_s={t_d:.2f};ofv={o_s:.1f};dev_dense={dev:+.3f}%;"
            f"gap={gap:.5f}")

        if run_dense:
            # the paper's competitive frame (Section 5.2): the exchange
            # heuristic (Papenberg & Klau's move set, vectorized sweeps)
            # on the same instance -- objective ratio + wall time vs ABA
            # is the first receipt for "as good as the rival, much faster
            # per unit quality" (sequential fast_anticlustering would be
            # Python-loop-bound at these n; the vectorized twin is the
            # honest at-scale variant)
            t0 = time.time()
            lab_e = exchange_anticlustering(np.asarray(x), k, seed=0)
            t_e = time.time() - t0
            o_e = float(objective_centroid(x, jnp.asarray(lab_e), k))
            ce = np.bincount(lab_e, minlength=k)
            assert ce.min() == ce.max(), "exchange lost balance"
            ratio = o_e / o_s
            rec.add(f"scale/exchange/n{n}_k{k}", f"{n}x{d}x{k}", t_e, o_e,
                    extra={"ofv_ratio_vs_aba": ratio, "aba_s": t_s})
            print(f"table10exch,{n},{d},{k},{t_e:.2f},{o_e:.1f},"
                  f"ratio={ratio:.4f}", flush=True)
            row(f"scale/exchange/n{n}_k{k}", t_e,
                f"ofv={o_e:.1f};ratio_vs_aba={ratio:.4f};aba_s={t_s:.2f}")

        if run_dense:
            # constraint (5) at scale: categorical streaming (the chunked
            # rank-in-category rearrangement lifted the old dense-only ban).
            # Strata come from k-means like the paper's Section 5.4 setup;
            # the extra columns record the XLA-measured temp footprint of
            # the streaming call next to the dense core's on the same
            # categorical problem -- the O(chunk*d) vs O(n*d) claim as a
            # measured number, not a docstring.
            n_strata = 4
            cats = kmeans_labels(np.asarray(x), n_strata)
            cat_j = jnp.asarray(cats, jnp.int32)
            _labels(x, k, chunk, max_k, "auction", cats=cats)
            lab_c, t_c, _ = _labels(x, k, chunk, max_k, "auction", cats=cats)
            o_c = float(objective_centroid(x, jnp.asarray(lab_c), k))
            for s in range(n_strata):
                cs = np.bincount(lab_c[cats == s], minlength=k)
                assert cs.max() - cs.min() <= 1, \
                    f"stream_categorical lost stratification (stratum {s})"
            mem_s = mem_d = -1
            if k <= max_k:  # flat route: lower the exact calls being timed
                mem_s = _temp_bytes(aba_stream, x, k, chunk,
                                    categories=cat_j, n_categories=n_strata,
                                    solver="auction")
                mem_d = _temp_bytes(aba_core, x[None], k,
                                    categories=cat_j[None],
                                    n_categories=n_strata, solver="auction")
            rec.add(f"scale/stream_categorical/n{n}_k{k}", f"{n}x{d}x{k}",
                    t_c, o_c, extra={"temp_bytes_stream": mem_s,
                                     "temp_bytes_dense": mem_d,
                                     "n_strata": n_strata})
            print(f"table10cat,{n},{d},{k},{chunk},{t_c:.2f},{o_c:.1f},"
                  f"mem_stream={mem_s},mem_dense={mem_d}", flush=True)
            row(f"scale/stream_categorical/n{n}_k{k}", t_c,
                f"ofv={o_c:.1f};temp_bytes_stream={mem_s};"
                f"temp_bytes_dense={mem_d}")

    rec.write(json_path)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes (10^6 objects)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes only (CI smoke step)")
    ap.add_argument("--json", default="BENCH_scale.json",
                    help="trajectory output path (BENCH_SCHEMA rows)")
    args = ap.parse_args()
    run(full=args.full, smoke=args.smoke, json_path=args.json)
