"""Kernel + solver microbenchmarks.

The Pallas kernels only *interpret* on CPU, so wall-times here cover the
jnp reference paths and the auction solver; the kernels' performance story
on TPU is carried by the roofline analysis (BlockSpec arithmetic intensity,
see EXPERIMENTS.md S`Roofline).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.assignment import auction_solve, scipy_solve
from repro.kernels import cdist_ref

from benchmarks.common import row, timed


def run(full: bool = False):
    rng = np.random.default_rng(0)
    for m, k, d in [(512, 512, 64), (1024, 1024, 256)]:
        x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        _, t = timed(lambda: cdist_ref(x, c).block_until_ready(), repeats=5)
        ai = (2 * m * k * d) / ((m * d + k * d + m * k) * 4)
        row(f"kernel/cdist_ref/{m}x{k}x{d}", t,
            f"arith_intensity={ai:.1f}flops_per_byte")
    for n in (64, 128, 256) + ((512,) if full else ()):
        cmat = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        _, t_a = timed(lambda: auction_solve(cmat).block_until_ready(),
                       repeats=3)
        cn = np.asarray(cmat)
        _, t_s = timed(lambda: scipy_solve(cn), repeats=3)
        row(f"solver/auction/{n}", t_a, f"scipy_lapjv_us={t_s*1e6:.0f}")


if __name__ == "__main__":
    run()
