"""Kernel + solver microbenchmarks.

Reports three stories:

1. ``cdist`` reference arithmetic intensity (roofline anchor).
2. **Fused vs naive bidding**: the auction round's top-2 reduction through
   the ``kernels.ops.bid_top2`` dispatch (Pallas kernel on TPU; the same
   kernel body under ``interpret=True`` on small-CPU, jnp reference on
   big-CPU) against the naive path that materializes the (m, k) value
   matrix every round.  On CPU the interpret path is Python-speed -- the
   row records which path the dispatch resolved so the numbers are honest;
   the TPU speedup story is carried by the roofline analysis.
3. **Batched vs vmapped solver**: one fused ``auction_solve`` loop over a
   (B, k, k) stack vs ``vmap`` over B scalar solves.
4. **Registry sweep**: every LAP backend in the solver registry
   (``repro.core.assignment.available_solvers``) on the same stack, so a
   ``register_solver``-ed backend shows up here with zero edits.
5. **Epoch bench (cold vs warm)**: one ``anticluster()`` one-shot epoch vs
   one warm ``AnticlusterEngine.repartition`` epoch on the same shape --
   the repeated-workload story (mini-batch creation per training epoch).
   The regression gate compares wall time per row (so a warm-path slowdown
   past 2x the checked-in baseline fails CI); both rows also record the
   anticlustering objective into the trajectory JSON for drift inspection,
   and the printed ``speedup=``/``obj_dev_pct=`` labels carry the
   warm-beats-cold evidence (the tested quality contract -- warm objective
   within 1% of cold -- lives in tests/test_engine.py).
6. **Warm re-entry schedule**: the adaptive infeasibility-scaled re-entry
   (default) against the legacy fixed jump-to-final-phase shortcut
   (``AuctionConfig(adaptive_reentry=False)``) -- the
   ``engine/epoch_warm_fixed`` row pins that adaptive is no worse on the
   steady-state shape.
7. **Sharded epoch bench**: the same cold/warm story through a mesh spec
   (``engine/epoch_{cold,warm}_sharded`` rows) -- one ``shard_map``
   executable carrying per-shard prices (``ShardedABAState``) across
   epochs; the shape id records the device count.

``--smoke`` runs tiny shapes only (the CI smoke step) and, like every run,
writes the machine-readable trajectory to ``BENCH_kernel.json``
(``benchmarks.common.BENCH_SCHEMA``) for the CI regression gate; the
nightly workflow runs the full (non-smoke) sweep including the full-size
epoch bench.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.assignment import (AuctionConfig, auction_solve,
                                   available_solvers, get_solver, scipy_solve)
from repro.kernels import bid_top2, bid_top2_ref, cdist, cdist_ref
from repro.kernels.ops import gather_path, gather_rows, resolve_path

from benchmarks.common import BenchRecorder, row, timed


def run(full: bool = False, smoke: bool = False,
        json_path: str = "BENCH_kernel.json"):
    rng = np.random.default_rng(0)
    rec = BenchRecorder()

    cdist_shapes = [(256, 256, 32)] if smoke else [(512, 512, 64),
                                                   (1024, 1024, 256)]
    for m, k, d in cdist_shapes:
        x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        _, t = timed(lambda: cdist_ref(x, c).block_until_ready(), repeats=5)
        ai = (2 * m * k * d) / ((m * d + k * d + m * k) * 4)
        row(f"kernel/cdist_ref/{m}x{k}x{d}", t,
            f"arith_intensity={ai:.1f}flops_per_byte")
        rec.add(f"kernel/cdist_ref/{m}x{k}x{d}", f"{m}x{k}x{d}", t)
        # leading-chunk-dim dispatch (the streaming path's call shape):
        # the same rows as (C, m/C, d) chunks against shared centroids
        xc = x.reshape(4, m // 4, d)
        _, t_c = timed(lambda: cdist(xc, c).block_until_ready(), repeats=5)
        row(f"kernel/cdist_chunked/4x{m // 4}x{k}x{d}", t_c,
            f"flat_us={t * 1e6:.1f};path={resolve_path(m, k)}")
        rec.add(f"kernel/cdist_chunked/4x{m // 4}x{k}x{d}",
                f"4x{m // 4}x{k}x{d}", t_c)

    # --- streaming chunk gather (double-buffered DMA on TPU) --------------
    # The per-chunk row movement of aba_stream: gather (m,) rows from an
    # (n, d) table, then the fused gather+cdist that hides the next block's
    # DMA behind the current block's compute.  On CPU both resolve to the
    # XLA reference gather (path= records it); the kernel path is exercised
    # under interpret=True by tests and measured for real on TPU.
    gat_shapes = [(4096, 512, 32)] if smoke else [(65536, 8192, 64)]
    for n_g, m_g, d_g in gat_shapes:
        tbl = jnp.asarray(rng.normal(size=(n_g, d_g)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, n_g, size=(m_g,)), jnp.int32)
        c = jnp.asarray(rng.normal(size=(64, d_g)).astype(np.float32))
        _, t_g = timed(lambda: gather_rows(tbl, idx).block_until_ready(),
                       repeats=5)
        row(f"kernel/gather_rows/{n_g}x{m_g}x{d_g}", t_g,
            f"path={gather_path()}")
        rec.add(f"kernel/gather_rows/{n_g}x{m_g}x{d_g}",
                f"{n_g}x{m_g}x{d_g}", t_g)
        _, t_gc = timed(
            lambda: cdist(tbl, c, idx=idx).block_until_ready(), repeats=5)
        row(f"kernel/cdist_gather/{n_g}x{m_g}x{d_g}", t_gc,
            f"gather_us={t_g * 1e6:.1f};path={gather_path()}")
        rec.add(f"kernel/cdist_gather/{n_g}x{m_g}x{d_g}",
                f"{n_g}x{m_g}x{d_g}", t_gc)

    # --- fused vs naive bidding round ------------------------------------
    bid_shapes = [(128, 256, 16)] if smoke else \
        [(512, 512, 64), (2048, 512, 64)] + ([(8192, 4096, 128)] if full else [])
    for m, k, d in bid_shapes:
        x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        p = jnp.asarray(rng.normal(size=(k,)).astype(np.float32))
        _, t_f = timed(lambda: bid_top2(x, c, p)[0].block_until_ready(),
                       repeats=3)
        _, t_n = timed(lambda: bid_top2_ref(x, c, p)[0].block_until_ready(),
                       repeats=3)
        row(f"kernel/bid_top2_fused/{m}x{k}x{d}", t_f,
            f"naive_us={t_n * 1e6:.1f};speedup={t_n / t_f:.2f}x;"
            f"path={resolve_path(m, k)}")
        rec.add(f"kernel/bid_top2_fused/{m}x{k}x{d}", f"{m}x{k}x{d}", t_f)

    # --- batched vs vmapped auction solver -------------------------------
    stack_shapes = [(8, 24)] if smoke else \
        [(16, 64), (64, 64)] + ([(64, 256)] if full else [])
    vmapped = jax.jit(jax.vmap(auction_solve))
    for B, n in stack_shapes:
        stack = jnp.asarray(rng.normal(size=(B, n, n)).astype(np.float32))
        _, t_b = timed(lambda: auction_solve(stack).block_until_ready(),
                       repeats=3)
        _, t_v = timed(lambda: vmapped(stack).block_until_ready(), repeats=3)
        row(f"solver/auction_batched/{B}x{n}", t_b,
            f"vmap_us={t_v * 1e6:.1f};speedup={t_v / t_b:.2f}x;"
            f"solves_per_s={B / t_b:.0f}")
        rec.add(f"solver/auction_batched/{B}x{n}", f"{B}x{n}", t_b)

    solver_ns = (24,) if smoke else (64, 128, 256) + ((512,) if full else ())
    for n in solver_ns:
        cmat = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        _, t_a = timed(lambda: auction_solve(cmat).block_until_ready(),
                       repeats=3)
        cn = np.asarray(cmat)
        _, t_s = timed(lambda: scipy_solve(cn), repeats=3)
        row(f"solver/auction/{n}", t_a, f"scipy_lapjv_us={t_s*1e6:.0f}")
        rec.add(f"solver/auction/{n}", f"{n}x{n}", t_a)

    # --- registry sweep: every registered LAP backend on one stack --------
    # (canonical price-carrying signature: solve -> (assignment, prices))
    B, n = (4, 16) if smoke else (16, 64)
    stack = jnp.asarray(rng.normal(size=(B, n, n)).astype(np.float32))
    for name in available_solvers():
        solver = get_solver(name)
        _, t = timed(
            lambda: solver.solve(stack, AuctionConfig())[0]
            .block_until_ready(), repeats=3)
        row(f"solver/registry/{name}/{B}x{n}", t,
            f"solves_per_s={B / t:.0f};"
            f"factored={'yes' if solver.factored else 'no'}")
        rec.add(f"solver/registry/{name}/{B}x{n}", f"{B}x{n}", t)

    # --- epoch bench: cold one-shot vs warm engine repartition ------------
    from repro.anticluster import AnticlusterEngine, AnticlusterSpec, \
        anticluster
    from repro.core.objective import objective_centroid

    n_e, k_e, d_e = (2048, 16, 8) if smoke else (
        (65536, 64, 16) if full else (16384, 64, 16))
    x = jnp.asarray(rng.normal(size=(n_e, d_e)).astype(np.float32))
    spec = AnticlusterSpec(k=k_e, plan=None, stats=False)
    cold_res, t_cold = timed(lambda: anticluster(x, spec), repeats=3)
    obj_cold = float(objective_centroid(x, cold_res.labels, k_e))

    engine = AnticlusterEngine(spec)
    _res0, state0 = engine.partition(x)  # compile + cold solve
    carry = {"state": state0}

    def warm_epoch():
        r, carry["state"] = engine.repartition(x, carry["state"])
        carry["res"] = r
        return r.labels

    _, t_warm = timed(warm_epoch, repeats=3)
    obj_warm = float(objective_centroid(x, carry["res"].labels, k_e))
    shape_e = f"{n_e}x{k_e}x{d_e}"
    row(f"engine/epoch_warm/{shape_e}", t_warm,
        f"cold_us={t_cold * 1e6:.1f};speedup={t_cold / t_warm:.2f}x;"
        f"obj_dev_pct={(obj_warm - obj_cold) / abs(obj_cold) * 100:.4f};"
        f"compiles={engine.compile_count}")
    rec.add(f"engine/epoch_cold/{shape_e}", shape_e, t_cold, obj_cold)
    rec.add(f"engine/epoch_warm/{shape_e}", shape_e, t_warm, obj_warm)

    # --- warm re-entry schedule: adaptive (default) vs legacy fixed -------
    # Same warm epoch with adaptive_reentry=False (always jump straight to
    # the final small-eps phase).  The adaptive default measures dual
    # infeasibility per solve and must be no worse on this steady-state
    # shape (it pays one probe bidding round, skips the same phases).
    engine_f = AnticlusterEngine(spec.replace(
        auction_config=AuctionConfig(adaptive_reentry=False)))
    _resf, statef = engine_f.partition(x)
    carry_f = {"state": statef}

    def warm_epoch_fixed():
        r, carry_f["state"] = engine_f.repartition(x, carry_f["state"])
        carry_f["res"] = r
        return r.labels

    _, t_warm_f = timed(warm_epoch_fixed, repeats=3)
    obj_warm_f = float(objective_centroid(x, carry_f["res"].labels, k_e))
    row(f"engine/epoch_warm_fixed/{shape_e}", t_warm_f,
        f"adaptive_us={t_warm * 1e6:.1f};"
        f"adaptive_vs_fixed={t_warm_f / t_warm:.2f}x")
    rec.add(f"engine/epoch_warm_fixed/{shape_e}", shape_e, t_warm_f,
            obj_warm_f)

    # --- sharded epoch bench: mesh engine cold vs warm --------------------
    # The distributed-session story: one shard_map executable, per-shard
    # warm prices (ShardedABAState).  Runs over every available device (the
    # CI smoke runs single-device; the mesh smoke job forces two).
    from jax.sharding import Mesh

    n_dev = jax.device_count()
    if k_e % n_dev or n_e % n_dev:
        n_dev = 1  # unplaceable device count: measure the 1-device mesh
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]).reshape(n_dev), ("data",))
    spec_s = AnticlusterSpec(k=k_e, mesh=mesh, data_axes=("data",),
                             stats=False)
    cold_s, t_cold_s = timed(lambda: anticluster(x, spec_s), repeats=3)
    obj_cold_s = float(objective_centroid(x, cold_s.labels, k_e))
    engine_s = AnticlusterEngine(spec_s)
    _res_s, state_s = engine_s.partition(x)
    carry_s = {"state": state_s}

    def warm_epoch_sharded():
        r, carry_s["state"] = engine_s.repartition(x, carry_s["state"])
        carry_s["res"] = r
        return r.labels

    _, t_warm_s = timed(warm_epoch_sharded, repeats=3)
    obj_warm_s = float(objective_centroid(x, carry_s["res"].labels, k_e))
    shape_s = f"{n_e}x{k_e}x{d_e}@{n_dev}dev"
    row(f"engine/epoch_warm_sharded/{shape_s}", t_warm_s,
        f"cold_us={t_cold_s * 1e6:.1f};speedup={t_cold_s / t_warm_s:.2f}x;"
        f"obj_dev_pct={(obj_warm_s - obj_cold_s) / abs(obj_cold_s) * 100:.4f};"
        f"compiles={engine_s.compile_count}")
    rec.add(f"engine/epoch_cold_sharded/{shape_s}", shape_s, t_cold_s,
            obj_cold_s)
    rec.add(f"engine/epoch_warm_sharded/{shape_s}", shape_s, t_warm_s,
            obj_warm_s)

    rec.write(json_path)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes only (CI smoke step)")
    ap.add_argument("--json", default="BENCH_kernel.json",
                    help="trajectory output path (BENCH_SCHEMA rows)")
    args = ap.parse_args()
    run(full=args.full, smoke=args.smoke, json_path=args.json)
