"""Paper Table 4: ABA vs fast_anticlustering (P-N5/P-R5/P-R50) vs Rand --
objective values and running times on the Table 2 dataset presets."""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.anticluster import anticluster
from repro.core import objective_centroid
from repro.core.baselines import fast_anticlustering, random_partition
from repro.data import synthetic

from benchmarks.common import dev_pct, row

DATASETS = ["travel", "npi", "creditcard", "plants", "survival", "mnist"]


def run(full: bool = False, ks=(5, 50)):
    cap = None if full else 20_000
    print("# table4: dataset,K,ofv_aba,dev_PN5,dev_PR5,dev_PR50,dev_rand,"
          "cpu_aba_s,cpu_PN5_s,cpu_PR5_s,cpu_PR50_s")
    for name in DATASETS:
        x = synthetic.load(name, max_n=cap)
        xj = jnp.asarray(x)
        n = len(x)
        for k in ks:
            t0 = time.time()
            la = np.asarray(anticluster(xj, k=k, stats=False).labels)
            t_aba = time.time() - t0
            oa = float(objective_centroid(xj, jnp.asarray(la), k))
            devs, times = [], []
            for partners, mode in ((5, "nearest"), (5, "random"),
                                   (50, "random")):
                t0 = time.time()
                lb = fast_anticlustering(x, k, n_partners=partners,
                                         partner_mode=mode, seed=0)
                times.append(time.time() - t0)
                ob = float(objective_centroid(xj, jnp.asarray(lb), k))
                devs.append(dev_pct(oa, ob))
            lr = random_partition(n, k, seed=0)
            dev_r = dev_pct(oa, float(objective_centroid(xj, jnp.asarray(lr),
                                                         k)))
            print(f"table4,{name},{k},{oa:.2f},"
                  + ",".join(f"{d:+.4f}" for d in devs + [dev_r]) + ","
                  + f"{t_aba:.3f}," + ",".join(f"{t:.3f}" for t in times),
                  flush=True)
            row(f"table4/{name}/k{k}/aba", t_aba,
                f"ofv={oa:.1f};dev_PR5={devs[1]:+.4f}%")


if __name__ == "__main__":
    run()
