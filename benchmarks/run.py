"""Benchmark entrypoint: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only table4,...]``
prints ``name,us_per_call,derived`` CSV rows plus per-table detail lines.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (hours on this CPU)")
    ap.add_argument("--only", default="",
                    help="comma list: table4,table6,fig7,table8,table9,"
                         "table11,kernels")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    from benchmarks import (fig7_hierarchical, kernel_bench, table4_quality,
                            table6_balance, table8_largek, table9_categories,
                            table11_kcut)

    jobs = [("table4", table4_quality), ("table6", table6_balance),
            ("fig7", fig7_hierarchical), ("table8", table8_largek),
            ("table9", table9_categories), ("table11", table11_kcut),
            ("kernels", kernel_bench)]
    print("name,us_per_call,derived")
    for name, mod in jobs:
        if only and name not in only:
            continue
        t0 = time.time()
        mod.run(full=args.full)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
