"""End-to-end training-pipeline benchmark: the paper's mini-batch motivation
as a CI-gated number.

Two questions, answered on a small registry model (`smollm-360m --reduced`)
over a synthetic LM corpus:

1. **Anticlustered vs random minibatches, tokens/s** -- what diverse
   batching costs (or doesn't) end to end.  Both arms run the same async
   training loop; the anticlustered arm additionally re-partitions every
   epoch through :class:`repro.train.pipeline.ABAPipeline`.

2. **Overlap efficiency** -- the tentpole claim: an epoch whose next
   partition is dispatched asynchronously (``ABAPipeline``: stats off the
   timed path, the solve drains under the train steps, syncs coalesced at
   the epoch boundary) must finish in less wall time than the incumbent
   synchronous sequencing (``ABABatchSequencer.epoch(e, features=...)`` --
   blocking solve + stats -- followed by the per-step-synced train loop, as
   ``launch.train`` ran before the pipeline).  ``--smoke`` self-gates
   ``overlapped < sequential`` over the summed measured epochs and exits
   non-zero on violation, so CI catches an overlap regression the moment a
   sync sneaks back into the epoch path.  On a single-core CPU container
   the asynchronously dispatched solve still executes on the one XLA
   execution queue, so the expected margin is the *work* the pipeline keeps
   off the timed path (stats + certificate, the blocking boundary, per-step
   syncs), a few percent of an epoch; the gate therefore compares 5-epoch
   sums and re-measures once before declaring a violation (scheduler noise
   passes the retry; a genuine blocking solve in the epoch path adds its
   full boundary cost every epoch and fails both attempts).

Emits ``BENCH_train.json`` (``benchmarks.common.BENCH_SCHEMA``); CI runs
``--smoke``, uploads the JSON and gates wall times via
``benchmarks.check_regression`` against ``benchmarks/baselines/``.
``--dp N`` places the engine and the train step on an N-way data-parallel
host mesh (the HomebrewNLP-style ``--xla_force_host_platform_device_count``
harness nightly runs); the self-gate applies only to the single-device
smoke -- forced host devices oversubscribe the physical cores, so overlap
wall times there are exercise, not measurement.
"""

from __future__ import annotations

import gc
import statistics
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.data.minibatch import ABABatchSequencer, random_sequencer_batches
from repro.data.synthetic import lm_token_stream
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.pipeline import ABAPipeline
from repro.train.train_step import make_train_step

from benchmarks.common import BenchRecorder, obs_disabled_overhead, row

# instrumented call sites one pipeline epoch crosses with tracing off
# (pipeline/wait span + pipeline/dispatch event + pipeline/epoch span +
# engine dispatch's enabled() check) -- the disabled-overhead gate
# multiplies the measured per-site cost by this
_OBS_SITES_PER_EPOCH = 4


def _drift(feats: np.ndarray, epoch: int) -> np.ndarray:
    """Deterministic per-epoch feature drift (stands in for encoder drift)."""
    r = np.random.default_rng(1000 + epoch)
    return (feats + 0.05 * r.normal(size=feats.shape)).astype(np.float32)


def _fresh_model(cfg, mesh, seq_len: int, total_steps: int):
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, mesh, OptConfig(lr=3e-3, warmup_steps=2, decay_steps=total_steps),
        loss_chunk=min(32, seq_len)))
    return params, opt, step


def _run_paired(cfg, mesh, tokens, feats, batch_size, n_epochs, seed,
                engine_mesh=None):
    """Both arms, interleaved epoch by epoch (seq e, then ovl e).

    Interleaving pairs each overlapped epoch with the sequential epoch
    measured seconds earlier, so slow machine drift (allocator state, a
    noisy neighbour on the core) hits both arms alike and cancels in the
    5-epoch sums the smoke gate compares.  The pairing is leak-free: the
    XLA CPU execution queue is FIFO, so the asynchronously dispatched solve
    drains before that epoch's own train steps and every epoch wall syncs
    all the work it enqueued -- nothing spills into the other arm's wall.

    Sequential arm: blocking ``sequencer.epoch`` boundary (solve + stats) +
    per-step-synced steps, as ``launch.train`` ran before the pipeline.
    Overlapped arm: ``ABAPipeline.epochs`` + one coalesced sync per epoch.
    Epoch 0 is the compile/warmup epoch for both; walls cover epochs 1..n.
    """
    seq = ABABatchSequencer(feats, batch_size, seed=seed, mesh=engine_mesh)
    pipe = ABAPipeline(feats, batch_size, seed=seed, mesh=engine_mesh)
    k = len(seq)
    params_s, opt_s, step = _fresh_model(cfg, mesh, tokens.shape[1],
                                         k * (n_epochs + 1))
    params_o, opt_o = params_s, opt_s  # same init; independent from here
    drifted = {e: _drift_chain(feats, e) for e in range(1, n_epochs + 1)}
    epochs_it = pipe.epochs(n_epochs + 1,
                            features=lambda e: drifted.get(e, feats))
    w_seq, w_ovl, losses = [], [], []
    loss_s = loss_o = float("nan")
    for e in range(n_epochs + 1):
        # -- sequential epoch e ------------------------------------------
        t0 = time.time()
        batches = seq.epoch(e, features=drifted[e] if e else None)
        for idx in batches:
            batch = {"tokens": jnp.asarray(tokens[idx])}
            params_s, opt_s, m = step(params_s, opt_s, batch)
            loss_s = float(m["loss"])  # per-step sync, as launch.train had
        if e:
            w_seq.append(time.time() - t0)
        # -- overlapped epoch e ------------------------------------------
        t0 = time.time()
        ep = next(epochs_it)           # boundary: wait + dispatch of e+1
        losses.clear()
        for idx in ep:
            batch = {"tokens": jnp.asarray(tokens[idx])}
            params_o, opt_o, m = step(params_o, opt_o, batch)
            losses.append(m["loss"])   # no sync inside the epoch
        loss_o = float(losses[-1])     # the one coalesced sync
        if e:
            w_ovl.append(time.time() - t0)
    epochs_it.close()
    assert pipe.engine.compile_count == 1, \
        "overlapped epochs must not retrace"
    return w_seq, loss_s, w_ovl, loss_o


def _drift_chain(feats: np.ndarray, epoch: int) -> np.ndarray:
    """_drift applied cumulatively 1..epoch (matches the sequential arm)."""
    f = feats
    for e in range(1, epoch + 1):
        f = _drift(f, e)
    return f


def _run_random(cfg, mesh, tokens, feats, batch_size, n_epochs, seed):
    """Random-batching arm, same async loop shape as the pipeline arm."""
    n = feats.shape[0]
    batches = random_sequencer_batches(n, batch_size, seed=seed)
    k = len(batches)
    params, opt, step = _fresh_model(cfg, mesh, tokens.shape[1],
                                     k * (n_epochs + 1))
    walls, loss, losses = [], float("nan"), []
    for e in range(n_epochs + 1):
        t0 = time.time()
        order = np.random.default_rng(seed * 100003 + e).permutation(k)
        losses.clear()
        for b in order:
            batch = {"tokens": jnp.asarray(tokens[batches[b]])}
            params, opt, m = step(params, opt, batch)
            losses.append(m["loss"])
        loss = float(losses[-1])
        if e:
            walls.append(time.time() - t0)
    return walls, loss


def run(full: bool = False, smoke: bool = False, dp: int = 1,
        json_path: str = "BENCH_train.json") -> int:
    assert not obs.enabled(), "timed arms must run with tracing disabled"
    if smoke:
        # 5 measured epochs: the overlap margin (~5% of an epoch at this
        # shape) needs a median over enough epochs to sit above wall noise
        n_docs, batch, seq_len, n_epochs = 4096, 64, 16, 5
    elif full:
        n_docs, batch, seq_len, n_epochs = 8192, 64, 32, 5
    else:
        n_docs, batch, seq_len, n_epochs = 4096, 64, 32, 3
    cfg = get_config("smollm-360m", reduced=True)
    mesh = make_host_mesh(dp, 1)
    engine_mesh = mesh if dp > 1 else None
    tokens, feats = lm_token_stream(n_docs, seq_len, cfg.vocab_size, seed=0)
    k = n_docs // batch
    tokens_per_epoch = k * batch * seq_len
    rec = BenchRecorder()
    shape = f"{n_docs}x{seq_len}xK{k}"
    print(f"# pipeline_bench: n_docs={n_docs} batch={batch} seq={seq_len} "
          f"K={k} epochs={n_epochs} dp={dp}", flush=True)

    def measure_pair():
        gc.collect()
        return _run_paired(cfg, mesh, tokens, feats, batch, n_epochs,
                           seed=0, engine_mesh=engine_mesh)

    w_seq, loss_seq, w_ovl, loss_ovl = measure_pair()
    gate = smoke and dp == 1
    if gate and not sum(w_ovl) < sum(w_seq):
        # one re-measure before declaring a violation: the honest margin on
        # a 1-core container is a few percent of an epoch, so a scheduler
        # hiccup can invert a single run; a real regression (blocking solve
        # back in the epoch path) repeats on the retry
        print("# overlap sum inverted "
              f"(ovl {sum(w_ovl):.3f}s vs seq {sum(w_seq):.3f}s); "
              "re-measuring once", flush=True)
        w_seq, loss_seq, w_ovl, loss_ovl = measure_pair()
    gc.collect()
    w_rnd, loss_rnd = _run_random(cfg, mesh, tokens, feats, batch,
                                  n_epochs, seed=0)

    seq_s = statistics.median(w_seq)
    ovl_s = statistics.median(w_ovl)
    rnd_s = statistics.median(w_rnd)
    tps_aba = tokens_per_epoch / ovl_s
    tps_rnd = tokens_per_epoch / rnd_s
    ratio = ovl_s / seq_s

    rec.add("train/anticlustered/tokens_per_s", shape, ovl_s, loss_ovl,
            extra={"tokens_per_s": tps_aba, "epochs": n_epochs, "dp": dp})
    rec.add("train/random/tokens_per_s", shape, rnd_s, loss_rnd,
            extra={"tokens_per_s": tps_rnd, "epochs": n_epochs, "dp": dp})
    rec.add("train/overlap/epoch", shape, ovl_s, None,
            extra={"sequential_s": seq_s, "ratio": ratio, "dp": dp,
                   "sum_overlapped_s": round(sum(w_ovl), 4),
                   "sum_sequential_s": round(sum(w_seq), 4),
                   "epoch_walls_overlapped": [round(w, 4) for w in w_ovl],
                   "epoch_walls_sequential": [round(w, 4) for w in w_seq]})
    row("train/anticlustered/tokens_per_s", ovl_s,
        f"tokens_per_s={tps_aba:.0f};loss={loss_ovl:.4f}")
    row("train/random/tokens_per_s", rnd_s,
        f"tokens_per_s={tps_rnd:.0f};loss={loss_rnd:.4f}")
    row("train/overlap/epoch", ovl_s,
        f"sequential_s={seq_s:.3f};ratio={ratio:.3f}")
    print(f"# anticlustered {tps_aba:.0f} tok/s (loss {loss_ovl:.4f})  "
          f"random {tps_rnd:.0f} tok/s (loss {loss_rnd:.4f})", flush=True)
    print(f"# overlap: overlapped {ovl_s:.3f}s/epoch vs sequential "
          f"{seq_s:.3f}s/epoch (ratio {ratio:.3f})", flush=True)
    rec.write(json_path)

    # observability cost gate: tracing-off instrumentation must be free at
    # epoch granularity, measured deterministically (per-site disabled-span
    # cost x sites per epoch vs the epoch wall), never by A/B timing
    per_site = obs_disabled_overhead()
    obs_overhead = per_site * _OBS_SITES_PER_EPOCH
    print(f"# obs disabled overhead: {per_site * 1e9:.0f} ns/site x "
          f"{_OBS_SITES_PER_EPOCH} sites = {obs_overhead * 1e6:.2f} "
          f"us/epoch ({obs_overhead / ovl_s * 100:.4f}% of epoch wall)",
          flush=True)
    assert obs_overhead <= 0.02 * ovl_s, \
        "disabled tracing exceeds 2% of the epoch wall"

    failures = []
    if gate:
        # the acceptance contract, self-gated: overlapping the epoch
        # partition with the train steps must beat running them back to back
        if not sum(w_ovl) < sum(w_seq):
            failures.append(
                f"overlapped epochs ({sum(w_ovl):.3f}s over {len(w_ovl)}) "
                f"not faster than sequential solve+train "
                f"({sum(w_seq):.3f}s)")
        if not (np.isfinite(loss_ovl) and np.isfinite(loss_rnd)):
            failures.append("non-finite training loss")
    for f in failures:
        print(f"# SMOKE-GATE FAIL: {f}", file=sys.stderr, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="nightly shape (longer epochs)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke shape + overlap self-gate")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel mesh width (train step + engine "
                    "placed on the mesh; needs that many JAX devices)")
    ap.add_argument("--json", default="BENCH_train.json",
                    help="trajectory output path (BENCH_SCHEMA rows)")
    args = ap.parse_args()
    sys.exit(run(full=args.full, smoke=args.smoke, dp=args.dp,
                 json_path=args.json))
