"""CI gate on the benchmark trajectory: fail if any smoke bench regresses.

Compares a freshly emitted ``BENCH_*.json`` (``benchmarks.common``'s
``BENCH_SCHEMA`` rows) against the checked-in baseline under
``benchmarks/baselines/`` and exits non-zero when any matching ``bench`` id
got more than ``--factor`` times slower.  Benches present only on one side
are reported but never fail the gate (new benchmarks should not need a
baseline update in the same commit to go green; stale baseline rows rot
loudly instead of silently).

Usage (exactly what ci.yml runs):

    python -m benchmarks.check_regression BENCH_kernel.json \
        benchmarks/baselines/BENCH_kernel.json --factor 2.0

Baselines are refreshed by copying a representative run's JSON over the
baseline file (they are wall-clock numbers from a CI-class machine; the 2x
default factor absorbs runner jitter, not algorithmic regressions).
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict[str, dict]:
    with open(path) as f:
        rows = json.load(f)
    return {r["bench"]: r for r in rows}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly emitted BENCH_*.json")
    ap.add_argument("baseline", help="checked-in baseline JSON")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="fail when wall_s exceeds factor * baseline")
    ap.add_argument("--min-seconds", type=float, default=0.005,
                    help="both sides are floored to this before the ratio: "
                         "sub-millisecond rows are pure scheduler jitter on "
                         "shared runners, so a 0.5ms bench only fails once "
                         "it crosses factor * max(baseline, floor)")
    args = ap.parse_args(argv)

    cur, base = load(args.current), load(args.baseline)
    failures, checked = [], 0
    for bench, row in sorted(cur.items()):
        b = base.get(bench)
        if b is None:
            print(f"NEW       {bench}: {row['wall_s']:.4f}s (no baseline)")
            continue
        checked += 1
        ratio = (max(row["wall_s"], args.min_seconds)
                 / max(b["wall_s"], args.min_seconds, 1e-9))
        status = "REGRESSED" if ratio > args.factor else "ok"
        print(f"{status:9s} {bench}: {row['wall_s']:.4f}s vs "
              f"baseline {b['wall_s']:.4f}s ({ratio:.2f}x floored)")
        if ratio > args.factor:
            failures.append((bench, ratio))
    for bench in sorted(set(base) - set(cur)):
        print(f"STALE     {bench}: in baseline but not emitted")

    if failures:
        print(f"\n{len(failures)} bench(es) regressed past "
              f"{args.factor:.1f}x: "
              + ", ".join(f"{b} ({r:.2f}x)" for b, r in failures))
        return 1
    print(f"\nregression gate OK ({checked} benches within "
          f"{args.factor:.1f}x of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
