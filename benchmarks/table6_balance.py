"""Paper Table 6: balanced diversity -- sd/range of per-anticluster diversity,
ABA vs exchange heuristic vs random (the paper's headline quality claim)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.anticluster import anticluster
from repro.core import diversity_stats
from repro.core.baselines import fast_anticlustering, random_partition
from repro.data import synthetic

from benchmarks.common import dev_pct, row

DATASETS = ["travel", "npi", "creditcard", "plants", "mnist"]


def run(full: bool = False, k: int = 5):
    cap = None if full else 20_000
    print("# table6: dataset,K,sd_aba,sd_dev_PR5,sd_dev_rand,"
          "range_aba,range_dev_PR5,range_dev_rand")
    for name in DATASETS:
        x = synthetic.load(name, max_n=cap)
        xj = jnp.asarray(x)
        la = np.asarray(anticluster(xj, k=k).labels)
        sd_a, rg_a = (float(v) for v in diversity_stats(xj, jnp.asarray(la), k))
        lb = fast_anticlustering(x, k, n_partners=5, seed=0)
        sd_b, rg_b = (float(v) for v in diversity_stats(xj, jnp.asarray(lb), k))
        lr = random_partition(len(x), k, seed=0)
        sd_r, rg_r = (float(v) for v in diversity_stats(xj, jnp.asarray(lr), k))
        print(f"table6,{name},{k},{sd_a:.4f},{dev_pct(sd_a, sd_b):+.1f},"
              f"{dev_pct(sd_a, sd_r):+.1f},{rg_a:.4f},"
              f"{dev_pct(rg_a, rg_b):+.1f},{dev_pct(rg_a, rg_r):+.1f}",
              flush=True)
        row(f"table6/{name}/k{k}", 0.0,
            f"sd_aba={sd_a:.4f};sd_dev_PR5={dev_pct(sd_a, sd_b):+.0f}%;"
            f"sd_dev_rand={dev_pct(sd_a, sd_r):+.0f}%")


if __name__ == "__main__":
    run()
