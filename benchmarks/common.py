"""Shared benchmark utilities: timing, CSV rows, CPU-scale dataset caps.

The paper's experiments ran multi-million-row datasets on a server CPU with
a C implementation; this container is a single Python-driven CPU core, so
each table uses size-capped presets by default (row-for-row with the paper's
dataset list) and ``--full`` lifts the caps.  Quality metrics (objective
deviations, balance statistics) are scale-representative either way; wall
times are indicative only and the TPU path is evaluated via the dry-run
roofline instead.
"""

from __future__ import annotations

import json
import time

import numpy as np
import jax.numpy as jnp

# The one benchmark-trajectory schema: every BENCH_*.json is a list of rows
# with exactly these keys.  ``bench`` is a stable slash-separated id (the
# regression gate matches on it), ``shape`` a human-readable "NxKxD" string,
# ``wall_s`` seconds (warm, compile excluded via timed()'s warmup call), and
# ``objective`` the workload's quality number (null for pure-speed kernels).
BENCH_SCHEMA = ("bench", "shape", "wall_s", "objective")


class BenchRecorder:
    """Accumulates schema rows and writes a machine-readable BENCH_*.json.

    CI uploads the JSON as a workflow artifact and feeds it to
    ``benchmarks.check_regression`` against the checked-in baseline under
    ``benchmarks/baselines/`` -- the benchmark *trajectory* is part of the
    test surface, not just a printout.
    """

    def __init__(self):
        self.rows: list[dict] = []

    def add(self, bench: str, shape: str, wall_s: float,
            objective: float | None = None,
            extra: dict | None = None):
        row = dict(zip(BENCH_SCHEMA, (
            bench, shape, float(wall_s),
            None if objective is None else float(objective))))
        if extra:
            # measured side-channels (peak-memory bytes, gap certificates...)
            # ride along; the regression gate only reads the schema keys, so
            # extra columns inform without ever breaking the baseline match.
            # Schema keys are reserved: an extra named "wall_s" would
            # silently overwrite the measurement the gate compares.
            clash = set(extra) & set(BENCH_SCHEMA)
            if clash:
                raise ValueError(
                    f"extra keys {sorted(clash)} collide with the BENCH "
                    f"schema {BENCH_SCHEMA}; rename the extra column(s)")
            row.update(extra)
        self.rows.append(row)

    def write(self, path: str):
        with open(path, "w") as f:
            json.dump(self.rows, f, indent=1)
        print(f"# wrote {len(self.rows)} rows -> {path}", flush=True)


def obs_disabled_overhead(iters: int = 20000) -> float:
    """Measured per-call cost (seconds) of a *disabled* ``repro.obs`` span.

    The serve/pipeline benches self-gate tracing's disabled-path overhead
    deterministically: per-span cost times the spans-per-request estimate
    must stay under 2% of the measured latency.  Asserts tracing is in fact
    off -- a stray enabled trace would invalidate every timed arm.
    """
    from repro import obs
    assert not obs.enabled(), \
        "obs tracing must be disabled during benchmark timing"
    t0 = time.perf_counter()
    for _ in range(iters):
        with obs.span("bench/noop"):
            pass
        obs.event("bench/noop")
    return (time.perf_counter() - t0) / iters


def timed(fn, *args, repeats: int = 1, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return out, (time.time() - t0) / repeats


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def dev_pct(ref: float, other: float) -> float:
    return (other - ref) / abs(ref) * 100.0


def kmeans_labels(x: np.ndarray, k: int, iters: int = 10,
                  seed: int = 0) -> np.ndarray:
    """Tiny Lloyd's k-means (paper Section 5.4 derives categories this way)."""
    rng = np.random.default_rng(seed)
    centers = x[rng.choice(len(x), k, replace=False)]
    for _ in range(iters):
        d = ((x[:, None, :] - centers[None]) ** 2).sum(-1) if len(x) < 20000 \
            else np.stack([((x - c) ** 2).sum(1) for c in centers], 1)
        lab = d.argmin(1)
        for g in range(k):
            pts = x[lab == g]
            if len(pts):
                centers[g] = pts.mean(0)
    return lab.astype(np.int32)
