"""Serving-tier load benchmark: SLOs vs offered QPS.

Open-loop load generation against the async :class:`AnticlusterRouter`:
requests (four near-shapes, 100-120 rows x 4 dims, k=5 -- all inside the
128-row bucket) arrive on a fixed schedule at each offered QPS, carry a
latency deadline, and the sweep records per-point SLOs:

* ``serve/{mode}/qps{q}``      -- wall_s = p50 latency, objective =
  achieved throughput (completed req/s)
* ``serve/{mode}/qps{q}/p99``  -- wall_s = p99 latency, objective =
  shed rate (deadline + backpressure rejections / offered)

Two modes at every point, same spec and same traffic:

* ``cont`` -- continuous batching (``max_group=8``, row buckets on):
  queued requests join the next in-flight stacked call, so under load the
  service amortizes one solve across up to 8 requests.
* ``seq`` -- sequential warm serving (``max_group=1``, row buckets off):
  the pre-router baseline; every request is its own warm solo solve.

The acceptance story is the crossover: at an offered load past seq's
single-stream capacity (~1/solve_time), cont sustains higher throughput at
equal offered QPS.  The run FAILS (exit 1) if cont never beats seq --
continuous batching earning its complexity is part of the gated
trajectory, not a narrative claim.

``--smoke`` sweeps two points (one in-capacity, one past seq capacity)
with short windows -- the CI step; the nightly full sweep adds the low-
and high-QPS extremes and longer windows.  Wall times are CI-runner
indicative; the regression gate's 2x factor + 5ms floor absorb jitter.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro import obs
from repro.serve import AnticlusterRouter, Rejected

from benchmarks.common import BenchRecorder, obs_disabled_overhead

# instrumented call sites a single served request crosses with tracing off
# (admit event + queue-wait event + serve/solve span + engine/repartition
# begin check + resolve latency record + headroom) -- the disabled-overhead
# gate multiplies the measured per-site cost by this
_OBS_SITES_PER_REQUEST = 6

SIZES = (100, 104, 112, 120)   # near-shapes sharing the 128-row bucket
D, K = 4, 5
DEADLINE_S = 2.0


def _make_router(mode: str) -> AnticlusterRouter:
    if mode == "cont":
        return AnticlusterRouter(k=K, plan=None, max_group=8)
    return AnticlusterRouter(k=K, plan=None, max_group=1, row_buckets=False)


def _prewarm(router: AnticlusterRouter, xs) -> None:
    """Compile every lane the sweep can hit, then one warm pass."""
    if router.max_group > 1:
        for g in (8, 4, 2, 1):  # stacked group buckets at rows=128
            router.partition_many([xs[i % len(xs)] for i in range(g)])
    else:
        for x in xs:            # one solo lane per distinct shape
            router.partition(x)
    for x in xs:
        router.partition(x)


def drive(router: AnticlusterRouter, qps: float, duration: float,
          xs) -> dict:
    """Open-loop: submit on a fixed schedule, then wait out the backlog."""
    interval = 1.0 / qps
    tickets, rejected_full = [], 0
    t0 = time.monotonic()
    i = 0
    while i * interval < duration:
        target = t0 + i * interval
        now = time.monotonic()
        if target > now:
            time.sleep(target - now)
        try:
            tickets.append(router.submit(xs[i % len(xs)],
                                         deadline=DEADLINE_S))
        except Rejected:
            rejected_full += 1
        i += 1
    for t in tickets:
        try:
            t.result(timeout=duration + 10 * DEADLINE_S)
        except Rejected:
            pass
    wall = time.monotonic() - t0
    lat = sorted(t.latency for t in tickets if t.rejection is None)
    offered = i
    shed = offered - len(lat)
    return dict(
        offered=offered,
        completed=len(lat),
        throughput=len(lat) / wall,
        shed_rate=shed / offered if offered else 0.0,
        p50=lat[len(lat) // 2] if lat else float("nan"),
        p99=lat[min(len(lat) - 1, int(len(lat) * 0.99))] if lat
            else float("nan"),
    )


def run(smoke: bool = False, json_path: str = "BENCH_serve.json") -> int:
    # smoke points: 100 QPS sits well inside BOTH modes' capacity (stable
    # latencies; seq saturates ~175 on a CI-class core, so 150 would be
    # bimodal run-to-run) and 400 is decisively past seq's capacity
    qps_points = [100.0, 400.0] if smoke else [50.0, 100.0, 400.0, 600.0]
    duration = 3.0 if smoke else 6.0
    assert not obs.enabled(), "timed arms must run with tracing disabled"
    rng = np.random.default_rng(0)
    xs = [rng.normal(size=(n, D)).astype(np.float32) for n in SIZES]
    rec = BenchRecorder()
    thr: dict[tuple[str, float], float] = {}
    print("mode,qps,p50_ms,p99_ms,throughput_rps,shed_rate", flush=True)
    for mode in ("cont", "seq"):
        router = _make_router(mode)
        try:
            _prewarm(router, xs)
            for qps in qps_points:
                s = drive(router, qps, duration, xs)
                thr[(mode, qps)] = s["throughput"]
                shape = f"128x{D}@{qps:g}qps"
                rec.add(f"serve/{mode}/qps{qps:g}", shape, s["p50"],
                        s["throughput"])
                rec.add(f"serve/{mode}/qps{qps:g}/p99", shape, s["p99"],
                        s["shed_rate"])
                print(f"{mode},{qps:g},{s['p50'] * 1e3:.2f},"
                      f"{s['p99'] * 1e3:.2f},{s['throughput']:.1f},"
                      f"{s['shed_rate']:.3f}", flush=True)
        finally:
            router.close()
    rec.write(json_path)
    # observability cost gate: with tracing disabled (asserted inside the
    # helper) the per-site cost times the sites one request crosses must
    # stay under 2% of the cheapest measured p50 -- tracing-off must be
    # free at serving granularity, deterministically (no A/B timing noise)
    per_site = obs_disabled_overhead()
    p50_min = min(r["wall_s"] for r in rec.rows
                  if not r["bench"].endswith("/p99"))
    overhead = per_site * _OBS_SITES_PER_REQUEST
    print(f"# obs disabled overhead: {per_site * 1e9:.0f} ns/site x "
          f"{_OBS_SITES_PER_REQUEST} sites = {overhead * 1e6:.2f} us/req "
          f"({overhead / p50_min * 100:.3f}% of min p50 "
          f"{p50_min * 1e3:.1f} ms)", flush=True)
    assert overhead <= 0.02 * p50_min, \
        "disabled tracing exceeds 2% of serve p50"
    wins = [q for q in qps_points
            if thr[("cont", q)] > 1.1 * thr[("seq", q)]]
    if wins:
        best = max(wins, key=lambda q: thr[("cont", q)] / thr[("seq", q)])
        print(f"# continuous batching beats sequential at qps={best:g}: "
              f"{thr[('cont', best)]:.1f} vs {thr[('seq', best)]:.1f} rps",
              flush=True)
        return 0
    print("# FAIL: continuous batching never beat sequential serving",
          flush=True)
    return 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two-point sweep with short windows (CI)")
    ap.add_argument("--json", default="BENCH_serve.json")
    args = ap.parse_args()
    sys.exit(run(smoke=args.smoke, json_path=args.json))
