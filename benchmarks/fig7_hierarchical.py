"""Paper Figure 7: hierarchical decomposition settings -- objective vs
runtime for different factorizations of K (balanced factors fastest, quality
within a fraction of a percent)."""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.anticluster import anticluster
from repro.core import objective_centroid
from repro.data import synthetic

from benchmarks.common import row


def run(full: bool = False):
    n = 200_000 if full else 40_000
    d = 64 if full else 32
    k = 1000 if full else 500
    x = synthetic.make("lowrank", n, d, seed=0)
    xj = jnp.asarray(x)
    plans = ([(k,)] if k <= 500 else []) + [
        (2, k // 2), (5, k // 5), (10, k // 10), (20, k // 20),
    ]
    print(f"# fig7: imagenet32-like n={n} d={d} K={k}: plan,ofv,dev%,cpu_s")
    best = None
    for plan in plans:
        t0 = time.time()
        labels = np.asarray(anticluster(xj, k=k, plan=plan,
                                        stats=False).labels)
        dt = time.time() - t0
        o = float(objective_centroid(xj, jnp.asarray(labels), k))
        if best is None:
            best = o
        print(f"fig7,{'x'.join(map(str, plan))},{o:.2f},"
              f"{(o - best) / best * 100:+.4f},{dt:.2f}", flush=True)
        row(f"fig7/plan{'x'.join(map(str, plan))}", dt,
            f"ofv={o:.1f};dev={(o - best) / best * 100:+.4f}%")


if __name__ == "__main__":
    run()
