"""Render EXPERIMENTS.md S`Dry-run / S`Roofline tables from
dryrun_results.json (produced by repro.launch.dryrun)."""

from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def lever(r) -> str:
    """One sentence: what would move this cell's dominant term down."""
    arch, shape, dom = r["arch"], r["shape"], r["dominant"]
    if arch == "aba-pipeline":
        return "Lemma-1 hierarchical plan cuts auction rounds (S`Perf C: 5.1x)"
    ssm = arch.startswith(("falcon", "jamba"))
    if dom == "collective_s":
        return ("batch/multi-token decode amortizes the per-step psum "
                "latency of tiny SSM state updates")
    if shape == "train_4k":
        if ssm:
            return ("chunked selective scan keeps SSM state in registers "
                    "(S`Perf A: 9.8x); Pallas fused-backward kernel next")
        return ("sequence-parallel residuals + larger flash kv-chunks "
                "(S`Perf B: 3.0x); Pallas flash kernel keeps acc in VMEM")
    if shape == "prefill_32k":
        return ("flash loop-carry traffic scales with S/ck: larger kv "
                "chunks; Pallas attention kernel removes acc round-trips")
    if shape in ("decode_32k", "long_500k"):
        if "deepseek" in arch:
            return ("already MLA-compressed cache (9x smaller than GQA); "
                    "quantized (int8) cache next")
        return ("cache streaming is the floor: MLA-style compression or "
                "int8 KV cache; sliding-window layers could ring-buffer")
    return "-"


def render(path="dryrun_results.json", mesh="16x16"):
    rs = json.load(open(path))
    rows = [r for r in rs if r["mesh"] == mesh]
    out = []
    out.append("| arch | shape | status | compute_s | memory_s | coll_s | "
               "dominant | MODEL/HLO | HBM/dev | temp/dev | lever |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                       f"{r.get('reason', '')[:40]} | | | | | | | | |")
            continue
        t = r["terms"]
        mem = r.get("memory", {})
        ratio = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {r['dominant'].replace('_s','')} "
            f"| {ratio:.3f} | {fmt_bytes(r['bytes_per_device'])} "
            f"| {fmt_bytes(mem.get('temp_bytes'))} | {lever(r)} |")
    return "\n".join(out)


def summary(path="dryrun_results.json"):
    rs = json.load(open(path))
    ok = [r for r in rs if r["status"] == "ok"]
    sk = [r for r in rs if r["status"] == "skipped"]
    er = [r for r in rs if r["status"] == "error"]
    lines = [f"cells: {len(rs)} total, {len(ok)} compiled ok, "
             f"{len(sk)} skipped (documented), {len(er)} errors"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    lines.append(f"dominant terms: {doms}")
    worst = sorted((r for r in ok if r["mesh"] == "16x16"),
                   key=lambda r: r.get("useful_flops_ratio") or 9)[:5]
    lines.append("worst MODEL/HLO flop ratios (16x16): " + ", ".join(
        f"{r['arch']}/{r['shape']}={r.get('useful_flops_ratio'):.3f}"
        for r in worst))
    return "\n".join(lines)


if __name__ == "__main__":
    p = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    print(summary(p))
    print()
    print("## 16x16 (single pod)")
    print(render(p, "16x16"))
    print()
    print("## 2x16x16 (multi-pod)")
    print(render(p, "2x16x16"))
