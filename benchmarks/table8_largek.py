"""Paper Table 8: very large K via hierarchical decomposition (the mini-batch
regime: anticluster size down to 2-3) vs random partitioning."""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.anticluster import anticluster
from repro.core import objective_centroid
from repro.core.baselines import random_partition
from repro.data import synthetic

from benchmarks.common import dev_pct, row


def run(full: bool = False):
    n = 1_281_167 if full else 131_072
    d = 192 if full else 48
    x = synthetic.make("lowrank", n, d, seed=0)
    xj = jnp.asarray(x)
    ks = [n // 128, n // 32, n // 8, n // 4, n // 2]  # sizes 128 ... 2
    print(f"# table8: imagenet-like n={n} d={d}: K,min_sz,max_sz,"
          "cpu_aba_s,ofv_aba,ofv_rand,dev%")
    for i, k in enumerate(ks):
        t0 = time.time()
        labels = np.asarray(anticluster(xj, k=k, max_k=256,
                                stats=False).labels)
        dt = time.time() - t0
        if i == 0:
            # batched-vs-vmapped solver throughput on the same workload:
            # the hierarchical levels as ONE batched auction call per scan
            # step vs the legacy vmap over per-group scalar solves.  Both
            # paths are warmed first so jit compilation stays out of the
            # timed window (the headline dt above deliberately includes it).
            t1 = time.time()
            np.asarray(anticluster(xj, k=k, max_k=256,
                                   stats=False).labels)
            dt_batched = time.time() - t1
            np.asarray(anticluster(xj, k=k, max_k=256, batched=False,
                       stats=False).labels)  # warmup
            t2 = time.time()
            np.asarray(anticluster(xj, k=k, max_k=256, batched=False,
                                   stats=False).labels)
            dt_vmap = time.time() - t2
            row(f"table8/solver_batched_vs_vmap/k{k}", dt_batched,
                f"vmap_s={dt_vmap:.2f};"
                f"speedup={dt_vmap / max(dt_batched, 1e-9):.2f}x")
        counts = np.bincount(labels, minlength=k)
        oa = float(objective_centroid(xj, jnp.asarray(labels), k))
        lr = random_partition(n, k, seed=0)
        orr = float(objective_centroid(xj, jnp.asarray(lr), k))
        print(f"table8,{k},{counts.min()},{counts.max()},{dt:.2f},"
              f"{oa:.2f},{orr:.2f},{dev_pct(oa, orr):+.4f}", flush=True)
        row(f"table8/k{k}", dt, f"ofv={oa:.1f};dev_rand={dev_pct(oa, orr):+.2f}%")


if __name__ == "__main__":
    run()
