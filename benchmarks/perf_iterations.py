import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""S`Perf hillclimbing driver: lowers the three selected cells under a
sequence of hypothesis-driven configuration changes and records the roofline
terms for each (before/after pairs land in perf_results.json; the narrative
log lives in EXPERIMENTS.md S`Perf).

Cells (selection rationale in EXPERIMENTS.md):
  A falcon-mamba-7b/train_4k  -- worst memory term of the whole table
  B qwen2.5-14b/train_4k      -- flagship dense train; largest collective term
  C aba-pipeline/aba_1m       -- the paper's own technique on the mesh

Run:  PYTHONPATH=src python -m benchmarks.perf_iterations [--only A,B,C]
"""

import argparse
import json
import sys
import time

from repro.models.config import SSMSpec
from repro.launch import dryrun as D


def measure(name, arch, shape, overrides=None, aba_over=None):
    t0 = time.time()
    if arch == "aba-pipeline":
        rec = run_aba(shape, aba_over or {})
    elif arch == "pipeline-live":
        rec = run_pipeline_live(aba_over or {})
    else:
        rec = D.run_cell(arch, shape, multi_pod=False, overrides=overrides)
    rec["iter"] = name
    rec["wall_s"] = round(time.time() - t0, 1)
    line = {k: rec.get(k) for k in ("status", "dominant", "compile_s")}
    if rec.get("terms"):
        line |= {k: round(v, 4) for k, v in rec["terms"].items()}
        line["useful"] = round(rec.get("useful_flops_ratio") or 0, 3)
    print(f"[{name}] {line}", flush=True)
    return rec


def run_aba(shape, over):
    """ABA cell with plan/rounds/phases overrides."""
    import gc
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.assignment import AuctionConfig
    from repro.core.sharded import sharded_core
    from repro.launch import hlo_cost
    import traceback

    spec = dict(D.ABA_CELLS[shape])
    spec.update(over)
    mesh = D.make_production_mesh(multi_pod=False)
    acfg = AuctionConfig(fixed_rounds=spec["rounds"],
                         n_phases=spec.get("phases", 4))
    rec = {"arch": "aba-pipeline", "shape": shape, "mesh": "16x16",
           "devices": 256, "overrides": {k: str(v) for k, v in over.items()}}
    try:
        def fn(x):
            return sharded_core(x, spec["k"], mesh, data_axes="auto",
                               max_k=spec.get("max_k", 512),
                               auction_config=acfg)

        x_sh = NamedSharding(mesh, P(("data",), None))
        jitted = jax.jit(fn, in_shardings=(x_sh,),
                         out_shardings=NamedSharding(mesh, P(("data",))))
        args = (jax.ShapeDtypeStruct((spec["n"], spec["d"]), jnp.float32),)
        t0 = time.time()
        with mesh:
            compiled = jitted.lower(*args).compile()
        text = compiled.as_text()
        hc = hlo_cost.analyze(text)
        mem = compiled.memory_analysis()
        flops, byts = float(hc["flops"]), float(hc["bytes"])
        coll = float(hc["collective_bytes"])
        mf = D.aba_model_flops(spec, mesh)
        terms = {"compute_s": flops / D.PEAK_FLOPS,
                 "memory_s": byts / D.HBM_BW,
                 "collective_s": coll / D.LINK_BW}
        rec.update(status="ok", compile_s=round(time.time() - t0, 1),
                   flops_per_device=flops, bytes_per_device=byts,
                   collective_bytes_per_device=hc["collectives"],
                   terms=terms, dominant=max(terms, key=terms.get),
                   model_flops_total=mf, hlo_flops_total=flops * 256,
                   useful_flops_ratio=mf / (flops * 256) if flops else None,
                   memory=dict(temp_bytes=mem.temp_size_in_bytes),
                   unknown_trip_whiles=hc["unknown_trip_whiles"])
        del compiled, text
        gc.collect()
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def run_pipeline_live(over):
    """Live ``repro.train.pipeline`` cell: the dryrun rows above cost the
    ABA solve's HLO; this one actually consumes the pipeline's epoch
    iterator with a reduced registry model and records per-epoch walls --
    the overlap receipt at container scale (the heavy end-to-end arms live
    in ``benchmarks/pipeline_bench.py``)."""
    import traceback

    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.data.synthetic import lm_token_stream
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.train import ABAPipeline
    from repro.train.optimizer import OptConfig, adamw_init
    from repro.train.train_step import make_train_step

    spec = dict(n_docs=2048, batch=64, seq=16, epochs=3, refresh=True)
    spec.update(over)
    rec = {"arch": "pipeline-live", "shape": "train_small",
           "overrides": {k: str(v) for k, v in over.items()}}
    try:
        cfg = get_config("smollm-360m", reduced=True)
        mesh = make_host_mesh(1, 1)
        tokens, feats = lm_token_stream(spec["n_docs"], spec["seq"],
                                        cfg.vocab_size, seed=0)
        pipe = ABAPipeline(feats, spec["batch"], seed=0)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(
            cfg, mesh, OptConfig(lr=3e-3, warmup_steps=5,
                                 decay_steps=len(pipe) * spec["epochs"]),
            loss_chunk=spec["seq"]))

        def drifted(e):
            r = np.random.default_rng(1000 + e)
            return (feats + 0.02 * r.normal(size=feats.shape)
                    ).astype(np.float32)

        walls, losses = [], []
        for ep in pipe.epochs(spec["epochs"],
                              features=drifted if spec["refresh"] else None):
            t0 = time.time()
            ls = []
            for idx in ep:
                batch = {"tokens": jnp.asarray(tokens[idx])}
                params, opt, m = step(params, opt, batch)
                ls.append(m["loss"])
            losses.append(float(ls[-1]))  # one coalesced sync per epoch
            walls.append(round(time.time() - t0, 3))
        toks = len(pipe) * spec["batch"] * spec["seq"]
        rec.update(status="ok", epoch_walls=walls, losses=losses,
                   compile_count=pipe.engine.compile_count,
                   tokens_per_s_warm=round(toks / min(walls[1:]), 1),
                   overlapped=pipe.overlapped)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


ITERS = {
    "A": [
        ("A0 falcon train baseline (per-step scan)", "falcon-mamba-7b",
         "train_4k", {}, None),
        ("A1 falcon chunk=8 (fused SSM chunks)", "falcon-mamba-7b",
         "train_4k", {"ssm": SSMSpec(scan_chunk=8)}, None),
        ("A2 falcon chunk=16", "falcon-mamba-7b", "train_4k",
         {"ssm": SSMSpec(scan_chunk=16)}, None),
        ("A3 falcon chunk=32", "falcon-mamba-7b", "train_4k",
         {"ssm": SSMSpec(scan_chunk=32)}, None),
        # A4 = in-scan sharding anchors (code-level, applies to A1-A3 too)
        ("A4 falcon chunk=16 + scan anchors", "falcon-mamba-7b", "train_4k",
         {"ssm": SSMSpec(scan_chunk=16)}, None),
        ("A5 falcon chunk=16 + anchors + SP", "falcon-mamba-7b", "train_4k",
         {"ssm": SSMSpec(scan_chunk=16), "seq_parallel": True}, None),
    ],
    "B": [
        ("B0 qwen train baseline", "qwen2.5-14b", "train_4k", {}, None),
        ("B1 qwen embed dmodel-shard (no gather AR)", "qwen2.5-14b",
         "train_4k", {"embed_shard": "dmodel"}, None),
        ("B2 qwen chunk_kv=2048", "qwen2.5-14b", "train_4k",
         {"attn_chunk_kv": 2048}, None),
        ("B3 qwen chunk_kv=4096 (one kv step)", "qwen2.5-14b", "train_4k",
         {"attn_chunk_kv": 4096}, None),
        ("B4 qwen best combo", "qwen2.5-14b", "train_4k",
         {"embed_shard": "dmodel", "attn_chunk_kv": 2048}, None),
        # B5 = flash output anchor (code-level; baseline B0 predates it)
        ("B5 qwen flash out anchor", "qwen2.5-14b", "train_4k", {}, None),
        ("B6 qwen seq-parallel residuals", "qwen2.5-14b", "train_4k",
         {"seq_parallel": True}, None),
        ("B7 qwen anchor+SP+ck2048", "qwen2.5-14b", "train_4k",
         {"seq_parallel": True, "attn_chunk_kv": 2048}, None),
    ],
    "C": [
        ("C0 aba baseline flat K_local=512", "aba-pipeline", "aba_1m",
         None, {}),
        ("C1 aba hierarchical plan (Lemma 1: 8x64)", "aba-pipeline",
         "aba_1m", None, {"max_k": 64}),
        ("C2 aba hier + fewer rounds (64-col problems)", "aba-pipeline",
         "aba_1m", None, {"max_k": 64, "rounds": 96}),
        ("C3 aba hier + 2 eps phases", "aba-pipeline", "aba_1m",
         None, {"max_k": 64, "rounds": 96, "phases": 2}),
    ],
    "P": [
        ("P0 train pipeline, static membership", "pipeline-live",
         "train_small", None, {"refresh": False}),
        ("P1 train pipeline, overlapped per-epoch refresh", "pipeline-live",
         "train_small", None, {"refresh": True}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="A,B,C,P")
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args()
    try:
        results = json.load(open(args.out))
    except Exception:
        results = []
    done = {r.get("iter") for r in results}
    for group in args.only.split(","):
        for name, arch, shape, over, aba_over in ITERS[group.strip()]:
            if name in done:
                print(f"[skip] {name}", flush=True)
                continue
            results.append(measure(name, arch, shape, over, aba_over))
            with open(args.out + ".tmp", "w") as f:
                json.dump(results, f, indent=1)
            os.replace(args.out + ".tmp", args.out)


if __name__ == "__main__":
    main()
