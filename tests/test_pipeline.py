"""The training pipeline's contracts: async dispatch parity, bit-for-bit
pipeline-vs-sequencer determinism (1 device and on a 2-device mesh), the
compile-once pin, the host-callback fallback, and the refresh signature
guard (the silent-retrace bugfix)."""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.anticluster import AnticlusterEngine, AnticlusterSpec
from repro.data.minibatch import (ABABatchSequencer, build_batch_schedule,
                                  epoch_order)
from repro.launch.mesh import make_host_mesh
from repro.train.pipeline import ABAPipeline


def _feats(n=256, d=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _drift(f, e):
    r = np.random.default_rng(100 + e)
    return (f + 0.05 * r.normal(size=f.shape)).astype(np.float32)


def _drift_chain(f, e):
    for i in range(1, e + 1):
        f = _drift(f, i)
    return f


# ---------------------------------------------------------------- dispatch


def test_dispatch_wait_matches_repartition():
    """dispatch_repartition(...).wait() is bitwise the blocking repartition,
    stats included, on two independent warm sessions."""
    spec = AnticlusterSpec(k=8, plan="auto", max_k=512)
    e1, e2 = AnticlusterEngine(spec), AnticlusterEngine(spec)
    x = jnp.asarray(_feats())
    _, s1 = e1.partition(x)
    _, s2 = e2.partition(x)
    x2 = jnp.asarray(_drift(_feats(), 1))
    ra, sa = e1.repartition(x2, s1)
    pending = e2.dispatch_repartition(x2, s2)
    rb, sb = pending.wait()
    assert np.array_equal(np.asarray(ra.labels), np.asarray(rb.labels))
    assert np.array_equal(np.asarray(ra.cluster_sizes),
                          np.asarray(rb.cluster_sizes))
    assert float(ra.diversity_sd) == float(rb.diversity_sd)
    for a, b in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # wait() is idempotent: the same result object comes back
    rb2, sb2 = pending.wait()
    assert rb2 is rb and sb2 is sb
    assert e1.compile_count == 1 and e2.compile_count == 1


def test_dispatch_refuses_host_callback_solver():
    """scipy runs via pure_callback on the host thread: dispatching it could
    never overlap, so the engine refuses instead of pretending."""
    spec = AnticlusterSpec(k=4, plan=None, solver="scipy", chunk_size=None)
    eng = AnticlusterEngine(spec)
    x = jnp.asarray(_feats(64, 4))
    _, st = eng.partition(x)
    assert not eng.overlap_capable(x)
    with pytest.raises(RuntimeError, match="host callback"):
        eng.dispatch_repartition(x, st)


# ------------------------------------------------- pipeline vs sequencer


def _parity(mesh=None):
    """Pipeline labels + batch order must equal the sequencer's, per epoch."""
    feats = _feats()
    n_epochs = 4
    seq = ABABatchSequencer(feats, 32, seed=3, mesh=mesh)
    pipe = ABAPipeline(feats, 32, seed=3, mesh=mesh)
    for e, ep in enumerate(pipe.epochs(
            n_epochs, features=lambda i: _drift_chain(feats, i))):
        seq_batches = seq.epoch(e, features=_drift_chain(feats, e)
                                if e else None)
        assert np.array_equal(np.asarray(pipe.labels),
                              np.asarray(seq.result.labels))
        assert ep.index == e
        assert np.array_equal(ep.order, epoch_order(3, e, len(seq)))
        got = [np.asarray(b) for b in ep]
        assert len(got) == len(seq_batches)
        for a, b in zip(got, seq_batches):
            assert np.array_equal(a, b)
    assert seq.engine.compile_count == 1
    assert pipe.engine.compile_count == 1


def test_pipeline_matches_sequencer_bitwise():
    _parity()


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs 2 devices (mesh-smoke job forces them)")
def test_pipeline_matches_sequencer_bitwise_mesh():
    _parity(mesh=make_host_mesh(2, 1))


def test_pipeline_static_membership_rotates_order_only():
    """features=None: membership frozen (restore-replay), order rotates."""
    feats = _feats()
    pipe = ABAPipeline(feats, 32, seed=1)
    lab0 = pipe.labels.copy()
    orders = []
    for ep in pipe.epochs(3):
        orders.append(ep.order.copy())
        assert np.array_equal(pipe.labels, lab0)
    assert not np.array_equal(orders[0], orders[1])
    assert np.array_equal(orders[1], epoch_order(1, 1, len(pipe)))


def test_pipeline_abandoned_mid_epoch_recovers():
    """Breaking out mid-flight must finish the dispatched solve (its input
    state was donated) and leave the pipeline reusable."""
    feats = _feats()
    pipe = ABAPipeline(feats, 32, seed=0)
    for ep in pipe.epochs(4, features=lambda i: _drift_chain(feats, i)):
        break  # abandon with epoch 1's solve in flight
    # the generator's cleanup landed the in-flight result; a fresh iteration
    # starts from it without touching donated buffers
    ref = ABABatchSequencer(feats, 32, seed=0)
    ref.epoch(1, features=_drift_chain(feats, 1))
    assert np.array_equal(np.asarray(pipe.labels),
                          np.asarray(ref.result.labels))
    for ep in pipe.epochs(1, start_epoch=2):
        assert len(list(ep)) == len(pipe)
    assert pipe.engine.compile_count == 1


def test_pipeline_scipy_falls_back_loudly_same_bits():
    """A host-callback solver cannot overlap: one RuntimeWarning, then
    synchronous sequencing with identical labels."""
    feats = _feats(64, 4)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pipe = ABAPipeline(feats, 16, seed=0, solver="scipy")
        assert not pipe.overlapped
        labels = []
        for ep in pipe.epochs(3, features=lambda i: _drift_chain(feats, i)):
            labels.append(pipe.labels.copy())
    warns = [w for w in rec if issubclass(w.category, RuntimeWarning)
             and "host callback" in str(w.message)]
    assert len(warns) == 1  # loud, once
    # same bits as the blocking engine path on the same spec
    eng = AnticlusterEngine(pipe.engine.spec)
    res, st = eng.partition(jnp.asarray(feats))
    assert np.array_equal(labels[0], np.asarray(res.labels))
    for e in (1, 2):
        res, st = eng.repartition(
            jnp.asarray(_drift_chain(feats, e)), st)
        assert np.array_equal(labels[e], np.asarray(res.labels))
    assert pipe.engine.compile_count == 1


# ------------------------------------------- refresh signature validation


def test_refresh_rejects_mismatched_signature_instead_of_retracing():
    feats = _feats(256, 8)
    seq = ABABatchSequencer(feats, 32, seed=0)
    assert seq.engine.compile_count == 1
    with pytest.raises(ValueError, match="compiled signature"):
        seq.epoch(1, features=_feats(256, 9, seed=1))   # wrong width
    with pytest.raises(ValueError, match="compiled signature"):
        seq.refresh(_feats(128, 8, seed=1))             # too few rows
    with pytest.raises(TypeError, match="not numeric"):
        seq.refresh(feats.astype(np.complex64))
    # the guard fired before any engine call: still exactly one executable
    assert seq.engine.compile_count == 1
    seq.epoch(1, features=_drift(feats, 1))             # valid refresh
    assert seq.engine.compile_count == 1


def test_pipeline_epoch_schedule_helpers_agree():
    """build_batch_schedule is the single source of batch membership."""
    labels = np.random.default_rng(0).integers(0, 8, size=256)
    sched = build_batch_schedule(labels, 8)
    flat = np.concatenate([np.asarray(b) for b in sched])
    assert sorted(flat.tolist()) == list(range(256))
    for b, idx in enumerate(sched):
        assert np.all(labels[np.asarray(idx)] == labels[np.asarray(idx)][0])
