"""End-to-end behaviour tests: the launcher (train -> checkpoint -> preempt ->
restore -> identical continuation), serving, and a dry-run cell."""

import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

ROOT = __file__.rsplit("/tests/", 1)[0]


def test_train_restore_continuation(tmp_path):
    """Deterministic replay: train 12 steps straight vs 6 + restore + 6."""
    from repro.launch.train import main
    base = ["--arch", "smollm-360m", "--reduced", "--batch", "4",
            "--seq", "32", "--n-docs", "64", "--aba-batching",
            "--log-every", "50"]
    l_straight = main(base + ["--steps", "12"])
    main(base + ["--steps", "12", "--stop-after", "6",
                 "--ckpt-dir", str(tmp_path)])  # preempted run
    l_b = main(base + ["--steps", "12", "--ckpt-dir", str(tmp_path)])
    assert abs(l_straight - l_b) < 1e-4, (l_straight, l_b)


def test_generate_serving():
    from repro.models.registry import get_config
    from repro.models import transformer as T
    from repro.serve.generate import Generator
    cfg = get_config("smollm-360m", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    gen = Generator(cfg, params, max_len=48)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(3, 8)).astype(np.int32)
    out = gen.generate(prompts, 8)
    assert out.shape == (3, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    out2 = gen.generate(prompts, 8)
    np.testing.assert_array_equal(out, out2)  # greedy deterministic
    out3 = gen.generate(prompts, 8, temperature=1.0, seed=1)
    assert not np.array_equal(out, out3)


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real dry-run cell with 512 placeholder devices end-to-end."""
    code = textwrap.dedent("""
        import sys
        sys.path.insert(0, "src")
        from repro.launch.dryrun import run_cell
        import json
        rec = run_cell("smollm-360m", "decode_32k", multi_pod=True)
        print("JSON" + json.dumps({k: rec[k] for k in
            ("status", "dominant", "devices")}))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, cwd=ROOT)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("JSON")][0]
    rec = json.loads(line[4:])
    assert rec["status"] == "ok" and rec["devices"] == 512


def test_aba_vs_exchange_quality_and_runtime():
    """Paper Table 4 in miniature: comparable ofv, ABA not slower."""
    import time
    import jax.numpy as jnp
    from repro.core import aba, objective_centroid
    from repro.core.baselines import fast_anticlustering
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2000, 12)).astype(np.float32)
    k = 10
    labels = np.asarray(aba(jnp.asarray(x), k))  # includes compile
    t0 = time.time()
    labels = np.asarray(aba(jnp.asarray(x), k))
    t_aba = time.time() - t0
    t0 = time.time()
    lex = fast_anticlustering(x, k, n_partners=5, seed=0)
    t_ex = time.time() - t0
    oa = float(objective_centroid(jnp.asarray(x), jnp.asarray(labels), k))
    oe = float(objective_centroid(jnp.asarray(x), jnp.asarray(lex), k))
    assert oa >= oe * 0.995
    assert t_aba < t_ex * 2
