"""Multi-device semantics (8 forced host devices in a subprocess): MoE
shard_map parity, mesh-independence of the full models, sharded ABA,
compressed data-parallel training.

These run as subprocesses because jax pins the device count at first init
and the main pytest process must keep seeing exactly one CPU device.
"""

import subprocess
import sys
import textwrap

import pytest


def _run(body: str, devices: int = 8, timeout: int = 900):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, "src")
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, cwd=__file__.rsplit(
                           "/tests/", 1)[0])
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_mesh_independence_moe_archs():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.models.registry import get_config
        from repro.models import transformer as T
        key = jax.random.PRNGKey(0)
        for arch in ("jamba-v0.1-52b", "granite-moe-3b-a800m"):
            cfg = get_config(arch, reduced=True)
            params = T.init_params(cfg, key)
            tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
            m1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
            m2 = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
            l1 = np.asarray(T.forward(cfg, params, tokens, mesh=m1))
            with m2:
                l2 = np.asarray(jax.jit(lambda p, t: T.forward(cfg, p, t, mesh=m2))(params, tokens))
            err = float(np.abs(l1 - l2).max())
            assert err < 1e-3, (arch, err)
            print(arch, "ok", err)
    """)
    assert out.count("ok") == 2


@pytest.mark.slow
def test_sharded_aba_matches_local_hierarchy():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core.sharded import sharded_aba
        from repro.core.objective import balance_ok, objective_centroid
        from repro.core.baselines import random_partition
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        x = rng.normal(size=(512, 6)).astype(np.float32)
        with mesh:
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
            labels = np.asarray(sharded_aba(xs, 16, mesh, data_axes=("data",)))
        assert balance_ok(labels, 16, 512)
        o = float(objective_centroid(jnp.asarray(x), jnp.asarray(labels), 16))
        lr = random_partition(512, 16, seed=0)
        orr = float(objective_centroid(jnp.asarray(x), jnp.asarray(lr), 16))
        assert o > orr * 0.999, (o, orr)
        # per-shard locality: rows of shard s only get labels [s*4, s*4+4)
        for s in range(4):
            seg = labels[s * 128:(s + 1) * 128]
            assert seg.min() >= s * 4 and seg.max() < (s + 1) * 4
        print("ok", o, orr)
    """)
    assert "ok" in out


@pytest.mark.slow
def test_compressed_dp_training():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.models.registry import get_config
        from repro.models import transformer as T
        from repro.train.optimizer import OptConfig, adamw_init
        from repro.train.compression import (init_error_state,
                                             make_compressed_dp_train_step)
        cfg = get_config("smollm-360m", reduced=True)
        key = jax.random.PRNGKey(0)
        params = T.init_params(cfg, key)
        mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
        step = jax.jit(make_compressed_dp_train_step(
            cfg, mesh, OptConfig(lr=3e-3, warmup_steps=2, decay_steps=20),
            loss_chunk=8))
        opt = adamw_init(params)
        err = init_error_state(params)
        tokens = jax.random.randint(key, (32, 32), 0, cfg.vocab_size)
        losses = []
        with mesh:
            for i in range(12):
                params, opt, err, m = step(params, opt, err,
                                           {"tokens": tokens})
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0] - 0.3, losses
        print("ok", losses[0], losses[-1])
    """)
    assert "ok" in out


@pytest.mark.slow
def test_ef_compression_error_bounded():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.train.compression import _compress_leaf
        mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))
        rng = np.random.default_rng(0)
        gs = rng.normal(size=(8, 1000)).astype(np.float32)

        def local(g, e):
            out, err = _compress_leaf(g[0], e[0], ("data",))
            return out[None], err[None]

        f = shard_map(local, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")), check_vma=False)
        with mesh:
            out, err = f(jnp.asarray(gs), jnp.zeros_like(jnp.asarray(gs)))
        out = np.asarray(out)
        true_mean = gs.mean(0)
        # every shard holds the same compressed mean
        for s in range(8):
            np.testing.assert_allclose(out[s], out[0], atol=1e-7)
        rel = np.abs(out[0] - true_mean).max() / np.abs(true_mean).max()
        assert rel < 0.05, rel
        # error feedback: err ~= pre-quantization residual, bounded by scale
        assert np.abs(np.asarray(err)).max() <= np.abs(gs).max() / 127.0 * 2
        print("ok", rel)
    """)
    assert "ok" in out
