"""Auction solver vs exact Hungarian oracle + permutation properties."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.assignment import (AuctionConfig, assignment_value,
                                   auction_solve, greedy_solve, scipy_solve)


@pytest.mark.parametrize("n", [1, 2, 3, 5, 16, 64, 128])
@pytest.mark.parametrize("scale", [1.0, 100.0])
def test_auction_matches_hungarian(n, scale, rng):
    c = rng.normal(size=(n, n)).astype(np.float32) * scale
    a = np.asarray(auction_solve(jnp.asarray(c)))
    assert sorted(a) == list(range(n))
    va = assignment_value(c, a)
    vs = assignment_value(c, scipy_solve(c))
    assert va <= vs + 1e-4 * max(1.0, abs(vs))
    # eps-optimality bound: within n * eps_final of optimal
    span = c.max() - c.min()
    eps = span / (AuctionConfig().eps_end_mul * max(n, 1))
    assert vs - va <= n * eps + 1e-3 * scale


def test_auction_tight_config_exact(rng):
    cfg = AuctionConfig(n_phases=7, eps_end_mul=64.0)
    for _ in range(5):
        c = rng.normal(size=(32, 32)).astype(np.float32)
        a = np.asarray(auction_solve(jnp.asarray(c), cfg))
        vs = assignment_value(c, scipy_solve(c))
        assert abs(assignment_value(c, a) - vs) <= 1e-3


def test_auction_vmap(rng):
    import jax
    cs = rng.normal(size=(6, 24, 24)).astype(np.float32)
    outs = np.asarray(jax.vmap(auction_solve)(jnp.asarray(cs)))
    for c, a in zip(cs, outs):
        assert sorted(a) == list(range(24))
        vs = assignment_value(c, scipy_solve(c))
        assert vs - assignment_value(c, a) <= 0.05 * abs(vs) + 1e-3


def test_row_constant_invariance(rng):
    """Per-row constants don't change the OPTIMAL assignment (the ABA fast
    path drops ||x||^2); for the eps-optimal auction the gap is bounded by
    n*eps of the *shifted* span."""
    n = 20
    c = rng.normal(size=(n, n)).astype(np.float32)
    shift = rng.normal(size=(n, 1)).astype(np.float32) * 10
    # exact solver: strictly invariant
    s1 = scipy_solve(c)
    s2 = scipy_solve(c + shift)
    assert abs(assignment_value(c, s1) - assignment_value(c, s2)) < 1e-4
    # auction: bounded by the shifted problem's eps
    cfg = AuctionConfig(n_phases=6, eps_end_mul=32.0)
    a2 = np.asarray(auction_solve(jnp.asarray(c + shift), cfg))
    span = float((c + shift).max() - (c + shift).min())
    eps = span / (cfg.eps_end_mul * n)
    gap = assignment_value(c, s1) - assignment_value(c, a2)
    assert gap <= n * eps + 1e-3


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 20), seed=st.integers(0, 1000))
def test_auction_is_permutation(n, seed):
    c = np.random.default_rng(seed).normal(size=(n, n)).astype(np.float32)
    a = np.asarray(auction_solve(jnp.asarray(c)))
    assert sorted(a) == list(range(n))


def test_greedy_reasonable(rng):
    c = rng.normal(size=(30, 30)).astype(np.float32)
    g = np.asarray(greedy_solve(jnp.asarray(c)))
    assert sorted(g) == list(range(30))
    vs = assignment_value(c, scipy_solve(c))
    assert assignment_value(c, g) >= 0.5 * vs - 1.0


def test_fixed_rounds_auction(rng):
    """Fixed-length scan variant (dry-run profiling mode) stays valid and
    near-optimal; converged state is a fixed point."""
    c = rng.normal(size=(64, 64)).astype(np.float32)
    a = np.asarray(auction_solve(jnp.asarray(c),
                                 AuctionConfig(fixed_rounds=96)))
    assert sorted(a) == list(range(64))
    vs = assignment_value(c, scipy_solve(c))
    assert vs - assignment_value(c, a) <= 0.02 * abs(vs) + 1e-3
