"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU; on TPU the same BlockSpecs run
compiled)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import bid_top2, bid_top2_ref, cdist, cdist_ref


SHAPES = [(1, 1, 1), (7, 5, 3), (128, 128, 128), (130, 257, 70),
          (64, 512, 384), (200, 33, 1000)]


@pytest.mark.parametrize("m,n,d", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_cdist_allclose(m, n, d, dtype, rng):
    x = rng.normal(size=(m, d)).astype(dtype)
    c = rng.normal(size=(n, d)).astype(dtype)
    got = np.asarray(cdist(jnp.asarray(x), jnp.asarray(c), force="pallas"))
    ref = np.asarray(cdist_ref(jnp.asarray(x), jnp.asarray(c)))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (128, 256, 512)])
def test_cdist_block_shapes(bm, bn, bk, rng):
    x = rng.normal(size=(100, 200)).astype(np.float32)
    c = rng.normal(size=(150, 200)).astype(np.float32)
    got = np.asarray(cdist(jnp.asarray(x), jnp.asarray(c), force="pallas",
                           bm=bm, bn=bn, bk=bk))
    ref = np.asarray(cdist_ref(jnp.asarray(x), jnp.asarray(c)))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("m,n,d", SHAPES)
def test_bid_top2_allclose(m, n, d, rng):
    x = rng.normal(size=(m, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    p = rng.normal(size=(n,)).astype(np.float32)
    gv1, gj1, gv2 = (np.asarray(a) for a in bid_top2(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(p), force="pallas"))
    rv1, rj1, rv2 = (np.asarray(a) for a in bid_top2_ref(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(p)))
    np.testing.assert_allclose(gv1, rv1, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gv2, rv2, rtol=1e-3, atol=1e-3)
    # argmax can differ only on exact ties; check value equivalence
    vals = -2 * x @ c.T + (c * c).sum(1)[None] - p[None]
    np.testing.assert_allclose(vals[np.arange(m), gj1],
                               vals[np.arange(m), rj1], rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 50), n=st.integers(2, 80), d=st.integers(1, 40),
       seed=st.integers(0, 100))
def test_bid_top2_property(m, n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    p = rng.normal(size=(n,)).astype(np.float32)
    v1, j1, v2 = (np.asarray(a) for a in bid_top2(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(p), force="pallas"))
    assert (v1 >= v2 - 1e-4).all()
    assert ((0 <= j1) & (j1 < n)).all()


# --- streaming-chunk gather kernels (double-buffered DMA ring) -------------
# interpret=True executes the same make_async_copy ring in Python on CPU;
# on TPU the identical BlockSpecs run compiled.

GATHER_SHAPES = [(1, 1, 1), (200, 37, 8), (1000, 256, 32), (513, 300, 130)]


@pytest.mark.parametrize("n,m,d", GATHER_SHAPES)
def test_gather_rows_exact(n, m, d, rng):
    from repro.kernels.ops import gather_rows
    x = rng.normal(size=(n, d)).astype(np.float32)
    idx = rng.integers(0, n, size=(m,)).astype(np.int32)
    got = np.asarray(gather_rows(jnp.asarray(x), jnp.asarray(idx),
                                 force="pallas", bm=64))
    # a gather moves bytes, it does no arithmetic: parity must be bitwise
    np.testing.assert_array_equal(got, x[idx])


def test_gather_rows_clips_out_of_range(rng):
    from repro.kernels.ops import gather_rows
    x = rng.normal(size=(50, 9)).astype(np.float32)
    idx = np.array([0, 49, 200, -1], np.int32)  # kernel path clips to [0, n)
    got = np.asarray(gather_rows(jnp.asarray(x), jnp.asarray(idx),
                                 force="pallas", bm=8))
    np.testing.assert_array_equal(got, x[np.clip(idx, 0, 49)])


@pytest.mark.parametrize("n,m,d", GATHER_SHAPES)
def test_cdist_gather_fused_allclose(n, m, d, rng):
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(max(2, d // 2), d)).astype(np.float32)
    idx = rng.integers(0, n, size=(m,)).astype(np.int32)
    got = np.asarray(cdist(jnp.asarray(x), jnp.asarray(c),
                           idx=jnp.asarray(idx), force="pallas", bm=64))
    ref = np.asarray(cdist_ref(jnp.asarray(x[idx]), jnp.asarray(c)))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n,m,d", GATHER_SHAPES)
def test_bid_top2_gather_fused_allclose(n, m, d, rng):
    x = rng.normal(size=(n, d)).astype(np.float32)
    k = max(2, d // 2)
    c = rng.normal(size=(k, d)).astype(np.float32)
    p = rng.normal(size=(k,)).astype(np.float32)
    idx = rng.integers(0, n, size=(m,)).astype(np.int32)
    gv1, gj1, gv2 = (np.asarray(a) for a in bid_top2(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(p),
        idx=jnp.asarray(idx), force="pallas", bm=64, bn=128))
    rv1, rj1, rv2 = (np.asarray(a) for a in bid_top2_ref(
        jnp.asarray(x[idx]), jnp.asarray(c), jnp.asarray(p)))
    np.testing.assert_allclose(gv1, rv1, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gv2, rv2, rtol=1e-3, atol=1e-3)
    vals = -2 * x[idx] @ c.T + (c * c).sum(1)[None] - p[None]
    np.testing.assert_allclose(vals[np.arange(m), gj1],
                               vals[np.arange(m), rj1], rtol=1e-3, atol=1e-3)


def test_gather_wide_rows_fall_back_to_compose(rng):
    # d beyond the fused-kernel VMEM budget: the dispatcher must compose
    # gather + tiled cdist instead of launching the full-row kernel
    from repro.kernels.ops import _GATHER_FUSE_MAX_D
    d = _GATHER_FUSE_MAX_D + 16
    x = rng.normal(size=(40, d)).astype(np.float32)
    c = rng.normal(size=(4, d)).astype(np.float32)
    idx = rng.integers(0, 40, size=(16,)).astype(np.int32)
    got = np.asarray(cdist(jnp.asarray(x), jnp.asarray(c),
                           idx=jnp.asarray(idx), force="pallas"))
    ref = np.asarray(cdist_ref(jnp.asarray(x[idx]), jnp.asarray(c)))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 120), m=st.integers(1, 90), d=st.integers(1, 48),
       seed=st.integers(0, 100))
def test_gather_rows_property(n, m, d, seed):
    from repro.kernels.ops import gather_rows
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    idx = rng.integers(0, n, size=(m,)).astype(np.int32)
    got = np.asarray(gather_rows(jnp.asarray(x), jnp.asarray(idx),
                                 force="pallas", bm=32))
    np.testing.assert_array_equal(got, x[idx])


@pytest.mark.parametrize("s,di,ds,chunk", [(32, 64, 8, 8), (48, 128, 16, 16),
                                           (16, 512, 16, 4)])
def test_ssm_scan_allclose(s, di, ds, chunk, rng):
    from repro.kernels.ssm_scan import ssm_scan_pallas
    from repro.kernels.ref import ssm_scan_ref
    b = 2
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, di))).astype(np.float32)
                     * 0.1)
    bi = jnp.asarray(rng.normal(size=(b, s, ds)).astype(np.float32))
    co = jnp.asarray(rng.normal(size=(b, s, ds)).astype(np.float32))
    xi = jnp.asarray(rng.normal(size=(b, s, di)).astype(np.float32))
    a = jnp.asarray(-np.abs(rng.normal(size=(di, ds))).astype(np.float32))
    y_k, h_k = ssm_scan_pallas(dt, bi, co, xi, a, chunk=chunk, bdi=64,
                               interpret=True)
    y_r, h_r = ssm_scan_ref(dt, bi, co, xi, a)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)
