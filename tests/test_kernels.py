"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU; on TPU the same BlockSpecs run
compiled)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import bid_top2, bid_top2_ref, cdist, cdist_ref


SHAPES = [(1, 1, 1), (7, 5, 3), (128, 128, 128), (130, 257, 70),
          (64, 512, 384), (200, 33, 1000)]


@pytest.mark.parametrize("m,n,d", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_cdist_allclose(m, n, d, dtype, rng):
    x = rng.normal(size=(m, d)).astype(dtype)
    c = rng.normal(size=(n, d)).astype(dtype)
    got = np.asarray(cdist(jnp.asarray(x), jnp.asarray(c), force="pallas"))
    ref = np.asarray(cdist_ref(jnp.asarray(x), jnp.asarray(c)))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (128, 256, 512)])
def test_cdist_block_shapes(bm, bn, bk, rng):
    x = rng.normal(size=(100, 200)).astype(np.float32)
    c = rng.normal(size=(150, 200)).astype(np.float32)
    got = np.asarray(cdist(jnp.asarray(x), jnp.asarray(c), force="pallas",
                           bm=bm, bn=bn, bk=bk))
    ref = np.asarray(cdist_ref(jnp.asarray(x), jnp.asarray(c)))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("m,n,d", SHAPES)
def test_bid_top2_allclose(m, n, d, rng):
    x = rng.normal(size=(m, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    p = rng.normal(size=(n,)).astype(np.float32)
    gv1, gj1, gv2 = (np.asarray(a) for a in bid_top2(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(p), force="pallas"))
    rv1, rj1, rv2 = (np.asarray(a) for a in bid_top2_ref(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(p)))
    np.testing.assert_allclose(gv1, rv1, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gv2, rv2, rtol=1e-3, atol=1e-3)
    # argmax can differ only on exact ties; check value equivalence
    vals = -2 * x @ c.T + (c * c).sum(1)[None] - p[None]
    np.testing.assert_allclose(vals[np.arange(m), gj1],
                               vals[np.arange(m), rj1], rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 50), n=st.integers(2, 80), d=st.integers(1, 40),
       seed=st.integers(0, 100))
def test_bid_top2_property(m, n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    p = rng.normal(size=(n,)).astype(np.float32)
    v1, j1, v2 = (np.asarray(a) for a in bid_top2(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(p), force="pallas"))
    assert (v1 >= v2 - 1e-4).all()
    assert ((0 <= j1) & (j1 < n)).all()


@pytest.mark.parametrize("s,di,ds,chunk", [(32, 64, 8, 8), (48, 128, 16, 16),
                                           (16, 512, 16, 4)])
def test_ssm_scan_allclose(s, di, ds, chunk, rng):
    from repro.kernels.ssm_scan import ssm_scan_pallas
    from repro.kernels.ref import ssm_scan_ref
    b = 2
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, di))).astype(np.float32)
                     * 0.1)
    bi = jnp.asarray(rng.normal(size=(b, s, ds)).astype(np.float32))
    co = jnp.asarray(rng.normal(size=(b, s, ds)).astype(np.float32))
    xi = jnp.asarray(rng.normal(size=(b, s, di)).astype(np.float32))
    a = jnp.asarray(-np.abs(rng.normal(size=(di, ds))).astype(np.float32))
    y_k, h_k = ssm_scan_pallas(dt, bi, co, xi, a, chunk=chunk, bdi=64,
                               interpret=True)
    y_r, h_r = ssm_scan_ref(dt, bi, co, xi, a)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)
