"""Trip-aware HLO cost parser: the dry-run profiler's correctness contract."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_multiplication():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze(_text(f, s, s))
    expect = 10 * 2 * 128 ** 3
    assert abs(r["flops"] - expect) / expect < 0.01
    assert r["unknown_trip_whiles"] == 0


def test_nested_scan():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = analyze(_text(g, s, s))
    expect = 20 * 2 * 64 ** 3
    assert abs(r["flops"] - expect) / expect < 0.01


def test_grad_flops_3x():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(y ** 2)
    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = analyze(_text(jax.grad(f, argnums=1), s, s))
    expect = 3 * 10 * 2 * 64 ** 3
    assert abs(r["flops"] - expect) / expect < 0.05


def test_bytes_positive_and_scaled():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    s = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    r = analyze(_text(f, s))
    # each iteration reads+writes ~4MB
    assert r["bytes"] >= 7 * 2 * 4 * 1024 * 1024 * 0.5
