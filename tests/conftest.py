import os
import sys

# CPU-only tests must see exactly ONE device (the dry-run forces 512 in its
# own subprocess); keep jax quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def one_device_mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
