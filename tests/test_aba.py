"""ABA core: vs the Algorithm-1 reference, constraint properties, variants,
hierarchical decomposition, quality vs baselines (the paper's claims)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (aba, aba_reference, balance_ok, cut_cost,
                        diversity_stats, hierarchical_aba,
                        interleave_permutation, objective_centroid,
                        objective_pairwise, total_pairwise)
from repro.core.baselines import exact_small, random_partition


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


@pytest.mark.parametrize("n,k", [(100, 4), (101, 4), (300, 7), (256, 16)])
def test_matches_reference_objective(n, k):
    x = _data(n, 6)
    lj = np.asarray(aba(jnp.asarray(x), k))
    lr = aba_reference(x, k)
    oj = float(objective_centroid(jnp.asarray(x), jnp.asarray(lj), k))
    orf = float(objective_centroid(jnp.asarray(x), jnp.asarray(lr), k))
    assert balance_ok(lj, k)
    assert abs(oj - orf) / orf < 2e-3  # eps-optimal LAP vs exact LAPJV


@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 200), k=st.integers(2, 10), seed=st.integers(0, 99))
def test_balance_property(n, k, seed):
    """Constraint (2): sizes within {floor(n/k), ceil(n/k)} -- always."""
    x = _data(n, 4, seed)
    labels = np.asarray(aba(jnp.asarray(x), k))
    assert balance_ok(labels, k, n)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99))
def test_beats_random(seed):
    x = _data(400, 5, seed)
    k = 8
    la = np.asarray(aba(jnp.asarray(x), k))
    lr = random_partition(400, k, seed=seed)
    oa = float(objective_pairwise(jnp.asarray(x), jnp.asarray(la), k))
    orr = float(objective_pairwise(jnp.asarray(x), jnp.asarray(lr), k))
    assert oa >= orr * 0.999


def test_exchange_vectorized_balance_and_quality():
    """The vectorized exchange heuristic (table10_scale's competitive frame):
    exact balance by construction, beats its random start, and lands within
    a few percent of ABA's objective at small n."""
    from repro.core.baselines import exchange_anticlustering
    x = _data(512, 6, 7)
    k = 8
    le = exchange_anticlustering(x, k, seed=7)
    counts = np.bincount(le, minlength=k)
    assert counts.min() == counts.max() == 512 // k
    la = np.asarray(aba(jnp.asarray(x), k))
    lr = random_partition(512, k, seed=7)
    oe = float(objective_centroid(jnp.asarray(x), jnp.asarray(le), k))
    oa = float(objective_centroid(jnp.asarray(x), jnp.asarray(la), k))
    orr = float(objective_centroid(jnp.asarray(x), jnp.asarray(lr), k))
    assert oe > orr
    assert oe >= 0.97 * oa


def test_balanced_diversity_vs_random():
    """Paper Table 6: ABA's per-cluster diversity spread is much smaller."""
    x = _data(600, 6, 3)
    k = 6
    la = np.asarray(aba(jnp.asarray(x), k))
    lr = random_partition(600, k, seed=3)
    sd_a, _ = (float(v) for v in diversity_stats(jnp.asarray(x), jnp.asarray(la), k))
    sd_r, _ = (float(v) for v in diversity_stats(jnp.asarray(x), jnp.asarray(lr), k))
    assert sd_a < sd_r


def test_interleave_permutation_props():
    for n, k in [(18, 6), (22, 6), (100, 7), (10, 10)]:
        p = interleave_permutation(n, k)
        assert sorted(p) == list(range(n))
    # paper Figure 1: n=18, k=6 -> round-robin of 6 sublists of length 3
    p = interleave_permutation(18, 6)
    assert list(p[:6]) == [0, 3, 6, 9, 12, 15]
    # paper Figure 2: n=22, k=6 -> 2 short sublists (len 3), 4 long (len 4),
    # leftovers (last of each long sublist) at the end
    p = interleave_permutation(22, 6)
    assert list(p[:6]) == [0, 3, 6, 10, 14, 18]
    assert list(p[-4:]) == [9, 13, 17, 21]


def test_interleave_better_for_small_anticlusters():
    x = _data(512, 6, 1)
    k = 256  # anticlusters of 2 (the matching case, Section 4.2)
    lb = np.asarray(aba(jnp.asarray(x), k, variant="base"))
    li = np.asarray(aba(jnp.asarray(x), k, variant="interleave"))
    ob = float(objective_pairwise(jnp.asarray(x), jnp.asarray(lb), k))
    oi = float(objective_pairwise(jnp.asarray(x), jnp.asarray(li), k))
    assert oi > ob


def test_categorical_constraint():
    rng = np.random.default_rng(5)
    x = _data(500, 5, 5)
    cats = rng.integers(0, 4, size=500).astype(np.int32)
    k = 6
    labels = np.asarray(aba(jnp.asarray(x), k, categories=jnp.asarray(cats),
                            n_categories=4))
    assert balance_ok(labels, k)
    for g in range(4):
        counts = np.bincount(labels[cats == g], minlength=k)
        ng = (cats == g).sum()
        assert counts.min() >= ng // k and counts.max() <= -(-ng // k)


def test_categorical_matches_reference():
    rng = np.random.default_rng(6)
    x = _data(300, 4, 6)
    cats = rng.integers(0, 3, size=300).astype(np.int32)
    lj = np.asarray(aba(jnp.asarray(x), 5, categories=jnp.asarray(cats),
                        n_categories=3))
    lr = aba_reference(x, 5, categories=cats)
    oj = float(objective_centroid(jnp.asarray(x), jnp.asarray(lj), 5))
    orf = float(objective_centroid(jnp.asarray(x), jnp.asarray(lr), 5))
    assert abs(oj - orf) / orf < 5e-3


def test_near_optimal_tiny():
    x = _data(10, 2, 7).astype(np.float64)
    _, opt = exact_small(x, 2)
    la = np.asarray(aba(jnp.asarray(x.astype(np.float32)), 2))
    w = float(objective_pairwise(jnp.asarray(x.astype(np.float32)),
                                 jnp.asarray(la), 2))
    assert w >= 0.95 * opt


def test_hierarchical_quality_and_balance():
    x = _data(1000, 8, 8)
    k = 40
    lh = np.asarray(hierarchical_aba(jnp.asarray(x), (5, 8)))
    lf = np.asarray(aba(jnp.asarray(x), k))
    assert balance_ok(lh, k)
    oh = float(objective_centroid(jnp.asarray(x), jnp.asarray(lh), k))
    of = float(objective_centroid(jnp.asarray(x), jnp.asarray(lf), k))
    # paper Fig 7: decomposition costs well under 1% objective
    assert (of - oh) / of < 0.01


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50))
def test_hierarchical_balance_property(seed):
    """Proposition 1: hierarchical sizes stay within one of each other."""
    n = int(np.random.default_rng(seed).integers(150, 400))
    x = _data(n, 4, seed)
    labels = np.asarray(hierarchical_aba(jnp.asarray(x), (3, 4)))
    assert balance_ok(labels, 12, n)


def test_masked_aba_ignores_padding():
    x = _data(120, 4, 9)
    xp = np.concatenate([x, np.full((30, 4), 7.7, np.float32)])
    mask = np.arange(150) < 120
    lm = np.asarray(aba(jnp.asarray(xp), 5, valid_mask=jnp.asarray(mask)))
    lo = np.asarray(aba(jnp.asarray(x), 5))
    om = float(objective_centroid(jnp.asarray(x), jnp.asarray(lm[:120]), 5))
    oo = float(objective_centroid(jnp.asarray(x), jnp.asarray(lo), 5))
    assert balance_ok(lm[:120], 5, 120)
    assert abs(om - oo) / oo < 5e-3


def test_cut_cost_equivalence():
    """Section 5.5: cut = total - within, so argmax W == argmin cut."""
    x = _data(80, 3, 10)
    la = np.asarray(aba(jnp.asarray(x), 4))
    lr = random_partition(80, 4, seed=1)
    xj = jnp.asarray(x)
    for lab in (la, lr):
        c = float(cut_cost(xj, jnp.asarray(lab), 4))
        w = float(objective_pairwise(xj, jnp.asarray(lab), 4))
        t = float(total_pairwise(xj))
        assert abs((c + w) - t) / t < 1e-5
