"""AnticlusterEngine session API: cold parity with the one-shot front door,
zeroed-state repartition == partition (bit-for-bit), warm-start quality,
compile-exactly-once across epochs, ABAState pytree round-trips, the
price-carrying solver-registry signature (+ legacy deprecation shim), the
engine-backed sequencer/folds consumers, and the serving shim."""

import pickle
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.anticluster import (ABAState, AnticlusterEngine, AnticlusterSpec,
                               anticluster, available_solvers, get_solver,
                               register_solver)
from repro.core.assignment import AuctionConfig, auction_solve
from repro.core.objective import balance_ok, objective_centroid


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# Cold parity: engine.partition == one-shot anticluster, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(k=7, plan=None),
    dict(k=24, plan=(4, 6)),
    dict(k=7, plan=None, chunk_size=100),
    dict(k=7, plan=None, solver="auction_fused"),
])
def test_partition_matches_oneshot(kw):
    x = jnp.asarray(_data(600, 6, 31))
    res, state = AnticlusterEngine(**kw).partition(x)
    one = anticluster(x, **kw)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(one.labels))
    assert res.plan == one.plan and res.solver == one.solver
    np.testing.assert_array_equal(np.asarray(state.prev_labels),
                                  np.asarray(res.labels))


def test_partition_matches_oneshot_categorical():
    rng = np.random.default_rng(32)
    x = jnp.asarray(_data(500, 5, 32))
    cats = rng.integers(0, 4, size=500).astype(np.int32)
    eng = AnticlusterEngine(k=5, plan=None, categories=cats)
    res, _ = eng.partition(x)
    one = anticluster(x, k=5, plan=None, categories=cats)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(one.labels))


def test_partition_matches_oneshot_stacked():
    rng = np.random.default_rng(33)
    x = rng.normal(size=(3, 40, 5)).astype(np.float32)
    vm = np.ones((3, 40), bool)
    vm[1, 37:] = False
    eng = AnticlusterEngine(k=5, plan=None, variant="base", valid_mask=vm)
    res, state = eng.partition(x)
    one = anticluster(x, k=5, plan=None, variant="base", valid_mask=vm)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(one.labels))
    assert state.prices[0].shape == (3, 5)
    assert state.moment_count.shape == (3,)
    np.testing.assert_array_equal(np.asarray(state.moment_count),
                                  [40.0, 37.0, 40.0])


# ---------------------------------------------------------------------------
# Zeroed state == partition (the cold-sentinel contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(k=6, plan=None),
    dict(k=12, plan=(3, 4)),
    dict(k=6, plan=None, chunk_size=64),
])
def test_repartition_zeroed_state_bit_identical(kw):
    x = jnp.asarray(_data(300, 5, 34))
    eng = AnticlusterEngine(**kw)
    res, _ = eng.partition(x)
    res0, _ = eng.repartition(x, eng.init_state(x))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(res0.labels))
    # and the shared executable never retraced between the two calls
    assert eng.compile_count == 1


# ---------------------------------------------------------------------------
# Warm starts: balanced, objective within 1% of cold, zero retraces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(k=8, plan=None),
    dict(k=24, plan=(4, 6)),
    dict(k=8, plan=None, chunk_size=100),
    dict(k=8, plan=None, solver="auction_fused"),
])
def test_warm_repartition_quality_and_compile_count(kw):
    rng = np.random.default_rng(35)
    x = _data(640, 6, 35)
    eng = AnticlusterEngine(**kw)
    res, state = eng.partition(jnp.asarray(x))
    k = eng.spec.k
    o_cold = float(objective_centroid(jnp.asarray(x), res.labels, k))
    for _ in range(3):  # drifting epochs, same shape
        x = x + rng.normal(size=x.shape).astype(np.float32) * 0.05
        res, state = eng.repartition(jnp.asarray(x), state)
        xj = jnp.asarray(x)
        assert res.balanced and balance_ok(np.asarray(res.labels), k, 640)
        o_warm = float(objective_centroid(xj, res.labels, k))
        o_ref = float(objective_centroid(
            xj, anticluster(xj, **kw).labels, k))
        assert abs(o_warm - o_ref) / abs(o_ref) < 0.01  # within 1% of cold
    assert eng.compile_count == 1  # one trace across all epochs
    del o_cold


def test_warm_prices_are_nonzero_and_recentered():
    x = jnp.asarray(_data(300, 4, 36))
    eng = AnticlusterEngine(k=6, plan=None)
    _, state = eng.partition(x)
    p = np.asarray(state.prices[0])
    assert (p != 0).any()              # real dual state was carried out
    np.testing.assert_allclose(p.max(axis=-1), 0.0, atol=1e-5)  # re-centered


def test_state_shape_mismatch_raises():
    eng = AnticlusterEngine(k=6, plan=None)
    x = jnp.asarray(_data(120, 4, 37))
    _, state = eng.partition(x)
    with pytest.raises(ValueError, match="state prices"):
        eng.repartition(jnp.asarray(_data(120, 4, 37)),
                        ABAState((jnp.zeros((1, 7), jnp.float32),),
                                 state.moment_sum, state.moment_count,
                                 state.prev_labels))


def test_engine_rejects_kplus_and_unbatched():
    # (mesh specs are first-class engine sessions since the distributed
    # redesign -- see tests/test_engine_sharded.py)
    with pytest.raises(NotImplementedError, match="anticluster"):
        AnticlusterEngine(k=4, kplus_moments=2)
    with pytest.raises(NotImplementedError, match="batched"):
        AnticlusterEngine(k=4, batched=False)


# ---------------------------------------------------------------------------
# ABAState pytree: jit / device_put / pickle round-trips
# ---------------------------------------------------------------------------

def test_state_is_a_registered_pytree():
    eng = AnticlusterEngine(k=6, plan=(2, 3))
    x = jnp.asarray(_data(180, 4, 38))
    _, state = eng.partition(x)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert all(isinstance(l, jax.Array) for l in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, ABAState)
    # through jit (identity) -- the engine's own executables do exactly this
    jitted = jax.jit(lambda s: s)(state)
    np.testing.assert_array_equal(np.asarray(jitted.prev_labels),
                                  np.asarray(state.prev_labels))
    for a, b in zip(jitted.prices, state.prices):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # device_put
    put = jax.device_put(state, jax.devices()[0])
    np.testing.assert_array_equal(np.asarray(put.moment_sum),
                                  np.asarray(state.moment_sum))
    # pickle (checkpointing a session)
    back = pickle.loads(pickle.dumps(jax.device_get(state)))
    np.testing.assert_array_equal(np.asarray(back.prev_labels),
                                  np.asarray(state.prev_labels))
    # a restored state keeps warm-starting the same engine
    res, _ = eng.repartition(x, jax.device_put(back))
    assert res.balanced


def test_pickled_state_round_trips_through_repartition():
    eng = AnticlusterEngine(k=5, plan=None)
    x = jnp.asarray(_data(150, 3, 39))
    res1, state = eng.partition(x)
    state2 = pickle.loads(pickle.dumps(jax.device_get(state)))
    res2, _ = eng.repartition(x, state2)
    res3, _ = eng.repartition(x, state)
    np.testing.assert_array_equal(np.asarray(res2.labels),
                                  np.asarray(res3.labels))


def test_init_state_moments_and_shapes():
    eng = AnticlusterEngine(k=12, plan=(3, 4))
    st = eng.init_state((240, 5))
    assert [tuple(p.shape) for p in st.prices] == [(1, 3), (3, 4)]
    assert st.moment_sum.shape == (5,) and float(st.moment_count) == 0.0
    assert st.prev_labels.shape == (240,)
    assert int(np.asarray(st.prev_labels).max()) == -1
    x = _data(240, 5, 40)
    _, st2 = eng.partition(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(st2.moment_sum), x.sum(0),
                               rtol=1e-4)
    assert float(st2.moment_count) == 240.0


# ---------------------------------------------------------------------------
# Solver registry: price-carrying signature + legacy deprecation shim
# ---------------------------------------------------------------------------

def test_registry_canonical_signature_returns_prices():
    solver = get_solver("auction")
    cost = jnp.asarray(_data(16, 16, 41) @ _data(16, 16, 41).T)
    assign, prices = solver.solve(cost, AuctionConfig(), None)
    assert sorted(np.asarray(assign)) == list(range(16))
    assert prices.shape == (16,)
    # warm re-solve accepts the returned prices
    assign2, _ = solver.solve(cost, AuctionConfig(), prices)
    assert sorted(np.asarray(assign2)) == list(range(16))


def test_mixed_cold_warm_stack_is_per_instance():
    """A cold (all-zero-price) instance inside a warm stack must keep its
    full epsilon ramp -- the warm shortcut is decided per instance, so a
    group whose re-centered duals degenerate to zeros (e.g. duplicated
    rows) is never forced down the single-phase path."""
    rng = np.random.default_rng(47)
    cs = jnp.asarray(rng.normal(size=(4, 20, 20)).astype(np.float32))
    a_cold, p_cold = auction_solve(cs, return_prices=True)
    p = np.array(p_cold - p_cold.max(axis=-1, keepdims=True))
    p[0] = 0.0  # instances 0/2 cold, 1/3 warm
    p[2] = 0.0
    a_mix, _ = auction_solve(cs, prices=jnp.asarray(p), return_prices=True)
    for b in range(4):
        assert sorted(np.asarray(a_mix[b])) == list(range(20))
    # cold instances are bit-identical to the all-cold solve
    np.testing.assert_array_equal(np.asarray(a_mix[0]), np.asarray(a_cold[0]))
    np.testing.assert_array_equal(np.asarray(a_mix[2]), np.asarray(a_cold[2]))


def test_adaptive_reentry_runs_midschedule_phases_when_drifted():
    """The warm path re-enters the eps schedule by measured infeasibility:
    near-equilibrium prices keep the single-final-phase fast path, prices
    carried across heavily drifted costs take mid-schedule phases -- and in
    both regimes the result stays a permutation with a near-cold objective.
    The legacy fixed shortcut stays available via adaptive_reentry=False."""
    from repro.core.assignment import assignment_value
    rng = np.random.default_rng(52)
    cost = jnp.asarray(rng.normal(size=(2, 24, 24)).astype(np.float32))
    _a, p = auction_solve(cost, return_prices=True)
    p = p - p.max(axis=-1, keepdims=True)
    drifted = cost + jnp.asarray(
        rng.normal(size=cost.shape).astype(np.float32)) * 2.0  # heavy drift
    for cfg in (AuctionConfig(), AuctionConfig(adaptive_reentry=False)):
        a_warm = auction_solve(drifted, cfg, prices=p)
        a_cold = auction_solve(drifted, cfg)
        for b in range(2):
            assert sorted(np.asarray(a_warm[b])) == list(range(24))
            v_warm = assignment_value(np.asarray(drifted[b]),
                                      np.asarray(a_warm[b]))
            v_cold = assignment_value(np.asarray(drifted[b]),
                                      np.asarray(a_cold[b]))
            assert v_warm >= v_cold - abs(v_cold) * 0.05
    # adaptive on near-equilibrium prices: unchanged steady-state behaviour
    a_eq = auction_solve(cost, prices=p)
    for b in range(2):
        assert sorted(np.asarray(a_eq[b])) == list(range(24))


def test_legacy_priceless_solver_shim_warns_and_works():
    name = "test_legacy_priceless"

    def old_style(cost, config=AuctionConfig()):
        return auction_solve(cost, config)

    if name not in available_solvers():
        with pytest.warns(DeprecationWarning, match="price-less"):
            register_solver(name, old_style)
    solver = get_solver(name)
    cost = jnp.asarray(_data(12, 12, 42))
    assign, prices = solver.solve(cost, AuctionConfig(), None)
    assert sorted(np.asarray(assign)) == list(range(12))
    np.testing.assert_array_equal(np.asarray(prices), np.zeros(12))  # cold
    # incoming prices pass through unchanged (warm start is a no-op)
    p_in = jnp.arange(12, dtype=jnp.float32)
    _, p_out = solver.solve(cost, AuctionConfig(), p_in)
    np.testing.assert_array_equal(np.asarray(p_out), np.asarray(p_in))
    # and the shimmed backend runs end to end through the engine
    eng = AnticlusterEngine(k=4, plan=None, solver=name)
    x = jnp.asarray(_data(80, 3, 42))
    r1, st = eng.partition(x)
    r2, _ = eng.repartition(x, st)
    np.testing.assert_array_equal(np.asarray(r1.labels),
                                  np.asarray(r2.labels))  # stays cold


def test_new_style_registration_does_not_warn():
    name = "test_new_style_priced"
    if name not in available_solvers():
        def new_style(cost, config=AuctionConfig(), prices=None):
            return auction_solve(cost, config, prices=prices,
                                 return_prices=True)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            register_solver(name, new_style)
    assert name in available_solvers()


# ---------------------------------------------------------------------------
# Engine-backed consumers: sequencer, folds, serving shim
# ---------------------------------------------------------------------------

def test_sequencer_epoch_refresh_compiles_once():
    """The PR-4 bugfix contract: per-epoch re-partitions reuse ONE compiled
    executable (no fresh tracers per epoch for an identical shape)."""
    from repro.data.minibatch import ABABatchSequencer
    rng = np.random.default_rng(43)
    feats = rng.normal(size=(512, 6)).astype(np.float32)
    seq = ABABatchSequencer(feats, 64, chunk_size=None)
    assert seq.engine.compile_count == 1
    for epoch in range(1, 4):
        feats = feats + rng.normal(size=feats.shape).astype(np.float32) * .05
        batches = list(seq.epoch(epoch, features=feats))
        assert len(batches) == len(seq)
        flat = np.sort(np.concatenate(batches))
        np.testing.assert_array_equal(flat, np.arange(512))  # exact partition
    assert seq.engine.compile_count == 1  # zero retraces after epoch 0


def test_sequencer_epoch_without_features_keeps_membership():
    from repro.data.minibatch import ABABatchSequencer
    feats = _data(256, 5, 44)
    seq = ABABatchSequencer(feats, 32, chunk_size=None)
    before = seq.batches.copy()
    list(seq.epoch(1))  # no features -> no re-partition
    np.testing.assert_array_equal(before, seq.batches)


def test_folds_engine_reuse():
    from repro.data.folds import aba_folds, fold_engine
    feats = _data(200, 4, 45)
    eng = fold_engine(5)
    l1 = aba_folds(feats, 5, engine=eng)
    l2 = aba_folds(feats, 5)  # throwaway engine, same labels (cold == cold)
    np.testing.assert_array_equal(l1, l2)
    assert balance_ok(l1, 5, 200)
    # second build through the shared engine: compiled once, still balanced
    l3 = aba_folds(feats + 0.05, 5, engine=eng)
    assert balance_ok(l3, 5, 200)
    assert eng.compile_count == 1


def test_service_stacks_and_matches_oneshot():
    from repro.serve import AnticlusterService
    rng = np.random.default_rng(46)
    svc = AnticlusterService(k=5, plan=None)
    reqs = ([rng.normal(size=(100, 4)).astype(np.float32) for _ in range(3)]
            + [rng.normal(size=(60, 4)).astype(np.float32) for _ in range(2)])
    order = [reqs[0], reqs[3], reqs[1], reqs[2], reqs[4]]  # interleaved
    outs = svc.partition_many(order)
    for r, x in zip(outs, order):
        one = anticluster(jnp.asarray(x), k=5, plan=None)
        np.testing.assert_array_equal(np.asarray(r.labels),
                                      np.asarray(one.labels))
        assert r.balanced and r.labels.shape == (x.shape[0],)
    # one stacked lane per (shape, bucket): 100-row burst of 3 pads to 4,
    # 60-row burst of 2 stacks at 2
    assert svc.lane_count == 2
    # a second burst reuses the warm lanes (no new lane, still balanced)
    outs2 = svc.partition_many(order)
    assert svc.lane_count == 2 and all(r.balanced for r in outs2)


def test_folds_engine_mismatch_raises():
    from repro.data.folds import aba_folds, fold_engine
    feats = _data(200, 4, 48)
    with pytest.raises(ValueError, match="n_folds=10"):
        aba_folds(feats, 10, engine=fold_engine(5))
    with pytest.raises(ValueError, match="stratification"):
        aba_folds(feats, 5, categories=np.zeros(200, np.int32),
                  engine=fold_engine(5))


def test_service_burst_remainder_uses_solo_lane():
    from repro.serve import AnticlusterService
    rng = np.random.default_rng(49)
    svc = AnticlusterService(k=4, plan=None, max_group=2)
    reqs = [rng.normal(size=(40, 3)).astype(np.float32) for _ in range(3)]
    outs = svc.partition_many(reqs)  # 2-stack + remainder of 1 -> solo lane
    for r, x in zip(outs, reqs):
        one = anticluster(jnp.asarray(x), k=4, plan=None)
        np.testing.assert_array_equal(np.asarray(r.labels),
                                      np.asarray(one.labels))
    assert svc.lane_count == 2  # ("stack", shape, 2) + ("solo", shape)
    # a later single request reuses the same solo lane
    svc.partition(reqs[0])
    assert svc.lane_count == 2


def test_service_rejects_per_dataset_specs():
    from repro.serve import AnticlusterService
    with pytest.raises(NotImplementedError, match="per-dataset"):
        AnticlusterService(k=4, categories=np.zeros(10, np.int32))


def test_service_max_group_one_serves_every_request():
    """max_group=1 degenerates every stack part to a singleton; each must
    land on the solo lane (a bug once dropped all but the last)."""
    from repro.serve import AnticlusterService
    rng = np.random.default_rng(53)
    svc = AnticlusterService(k=4, plan=None, max_group=1)
    reqs = [rng.normal(size=(40, 3)).astype(np.float32) for _ in range(3)]
    outs = svc.partition_many(reqs)
    assert all(r is not None and r.balanced for r in outs)
    one = anticluster(jnp.asarray(reqs[0]), k=4, plan=None)
    np.testing.assert_array_equal(np.asarray(outs[0].labels),
                                  np.asarray(one.labels))


def test_folds_engine_category_values_must_match():
    from repro.data.folds import aba_folds, fold_engine
    feats = _data(100, 3, 54)
    cats_a = np.zeros(100, np.int32)
    cats_b = np.ones(100, np.int32)
    eng = fold_engine(5, categories=cats_a)
    with pytest.raises(ValueError, match="stratification"):
        aba_folds(feats, 5, categories=cats_b, engine=eng)
    labels = aba_folds(feats, 5, categories=cats_a, engine=eng)
    assert balance_ok(labels, 5, 100)
