"""The delta-update subsystem: ``AnticlusterEngine.update`` and
``IncrementalPartition``.

Pins the PR's acceptance contracts: in-threshold deltas restore balance
via the restricted warm-price auction (kept rows never move); zero-delta
and over-threshold calls are bit-for-bit identical to a full warm
``repartition`` (the fallback is a contract, not an approximation); the
LP-duality certificate rides update results; and the guard rails
(mesh / categories / valid_mask / stale state) fail loudly up front.

Donation caveat for bit-for-bit tests: ``repartition``/``update`` consume
the state's buffers (donate_argnums), so any test comparing against a
hand-built carried state must snapshot prices/moments with ``jnp.array``
BEFORE the consuming call.
"""

import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.anticluster import (ABAState, AnticlusterEngine, AnticlusterSpec,
                               anticluster)
from repro.core.objective import balance_ok, objective_centroid
from repro.incremental import IncrementalPartition

from _hypothesis_compat import given, settings, st


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _counts_ok(labels, k):
    n = len(labels)
    c = np.bincount(np.asarray(labels), minlength=k)
    return c.min() >= n // k and c.max() <= -(-n // k)


def _snapshot(state):
    """Donation-safe copy of a state's buffers (see module docstring)."""
    return ABAState(
        prices=tuple(jnp.array(p) for p in state.prices),
        moment_sum=jnp.array(state.moment_sum),
        moment_count=jnp.array(state.moment_count),
        prev_labels=None if state.prev_labels is None
        else jnp.array(state.prev_labels))


# ---------------------------------------------------------------------------
# The delta path: arrivals / departures keep balance, kept rows never move
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(k=8, plan=None),
    dict(k=8, plan=None, solver="auction_fused"),
    dict(k=6, plan=(2, 3)),
])
def test_update_added_keeps_balance_and_kept_labels(kw):
    eng = AnticlusterEngine(**kw)
    x = jnp.asarray(_data(200, 5, seed=3))
    res0, st = eng.partition(x)
    added = jnp.asarray(_data(12, 5, seed=4))
    res, new_x, st2 = eng.update(x, st, added=added)
    assert res.updated
    assert new_x.shape == (212, 5)
    # kept rows come first, in original order, with their original labels
    np.testing.assert_array_equal(np.asarray(res.labels[:200]),
                                  np.asarray(res0.labels))
    np.testing.assert_array_equal(np.asarray(new_x[:200]), np.asarray(x))
    assert _counts_ok(res.labels, eng.spec.k)
    assert bool(balance_ok(res.labels, eng.spec.k))
    # the returned state is live: a follow-up delta keeps composing
    res3, _, _ = eng.update(new_x, st2, removed=np.arange(6))
    assert _counts_ok(res3.labels, eng.spec.k)


def test_update_removed_only_keeps_labels_when_balanced():
    eng = AnticlusterEngine(k=8, plan=None)
    x = jnp.asarray(_data(240, 4, seed=5))
    res0, st = eng.partition(x)
    lab0 = np.asarray(res0.labels)
    # remove one row per cluster: sizes stay exactly balanced, so the pure
    # departure path keeps every kept row's label verbatim
    rem = np.array([np.flatnonzero(lab0 == c)[0] for c in range(8)])
    res, new_x, _ = eng.update(x, st, removed=rem)
    assert res.updated and new_x.shape == (232, 4)
    keep = np.ones(240, bool)
    keep[rem] = False
    np.testing.assert_array_equal(np.asarray(res.labels), lab0[keep])
    np.testing.assert_array_equal(np.asarray(new_x), np.asarray(x)[keep])


def test_update_mixed_delta_objective_near_full_resolve():
    eng = AnticlusterEngine(k=16, plan=None)
    x = jnp.asarray(_data(800, 8, seed=6))
    _, st = eng.partition(x)
    added = jnp.asarray(_data(40, 8, seed=7))
    rem = np.sort(np.random.default_rng(8).choice(800, 40, replace=False))
    res, new_x, _ = eng.update(x, st, added=added, removed=rem)
    assert res.updated and _counts_ok(res.labels, 16)
    o_u = float(objective_centroid(new_x, res.labels, 16))
    o_f = float(objective_centroid(
        new_x, anticluster(new_x, k=16, plan=None).labels, 16))
    assert o_u >= 0.99 * o_f  # the local patch stays within 1% (acceptance)


def test_update_removed_bool_mask_equals_indices():
    eng = AnticlusterEngine(k=5, plan=None)
    x = jnp.asarray(_data(150, 3, seed=9))
    _, st_a = eng.partition(x)
    _, st_b = eng.partition(x)
    rem = np.array([3, 50, 149])
    mask = np.zeros(150, bool)
    mask[rem] = True
    res_a, xa, _ = eng.update(x, st_a, removed=rem)
    res_b, xb, _ = eng.update(x, st_b, removed=mask)
    np.testing.assert_array_equal(np.asarray(res_a.labels),
                                  np.asarray(res_b.labels))
    np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ---------------------------------------------------------------------------
# Property: add a batch, then remove those same rows -> balance restored
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(m=st.integers(min_value=1, max_value=20),
       seed=st.integers(min_value=0, max_value=10_000))
def test_update_add_then_remove_restores_balance(m, seed):
    eng = AnticlusterEngine(k=6, plan=None)
    x = jnp.asarray(_data(120, 4, seed=seed % 97))
    _, st = eng.partition(x)
    added = jnp.asarray(_data(m, 4, seed=seed))
    res1, x1, st1 = eng.update(x, st, added=added)
    assert _counts_ok(res1.labels, 6)
    # the added rows sit at the tail of the running matrix by contract
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)  # fallback allowed
        res2, x2, _ = eng.update(x1, st1,
                                 removed=np.arange(120, 120 + m))
    assert x2.shape == (120, 4)
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x))
    assert _counts_ok(res2.labels, 6)


# ---------------------------------------------------------------------------
# The fallback contract: zero-delta and over-threshold == repartition,
# bit-for-bit
# ---------------------------------------------------------------------------

def test_zero_delta_is_repartition_bitwise():
    eng = AnticlusterEngine(k=8, plan=None)
    x = jnp.asarray(_data(160, 4, seed=10))
    _, st_a = eng.partition(x)
    _, st_b = eng.partition(x)
    res_u, new_x, st_u = eng.update(x, st_a)
    res_r, st_r = eng.repartition(x, st_b)
    np.testing.assert_array_equal(np.asarray(res_u.labels),
                                  np.asarray(res_r.labels))
    np.testing.assert_array_equal(np.asarray(new_x), np.asarray(x))
    for pu, pr in zip(st_u.prices, st_r.prices):
        np.testing.assert_array_equal(np.asarray(pu), np.asarray(pr))


def test_over_threshold_falls_back_bitwise():
    from repro.incremental import _carried_state

    eng = AnticlusterEngine(k=8, plan=None, update_threshold=0.1)
    x = jnp.asarray(_data(160, 4, seed=11))
    _, st = eng.partition(x)
    snap = _snapshot(st)  # update() donates st's buffers
    added = jnp.asarray(_data(40, 4, seed=12))  # 40/200 = 0.2 > 0.1

    with pytest.warns(RuntimeWarning, match="full warm repartition"):
        res_u, new_x, _ = eng.update(x, st, added=added)
    assert res_u.updated is False  # provenance: the delta path did NOT run

    # the promise in the warning, verified literally: bit-for-bit identical
    # to repartition() of the post-delta rows with the carried state
    ref_x = jnp.concatenate([x, added])
    res_r, _ = eng.repartition(ref_x, _carried_state(snap, 200, added, None))
    np.testing.assert_array_equal(np.asarray(res_u.labels),
                                  np.asarray(res_r.labels))
    np.testing.assert_array_equal(np.asarray(new_x), np.asarray(ref_x))


def test_unrestorable_balance_falls_back():
    eng = AnticlusterEngine(k=6, plan=None)
    x = jnp.asarray(_data(120, 4, seed=13))
    res0, st = eng.partition(x)
    # removing many rows of one cluster leaves others over the new ceiling
    lab = np.asarray(res0.labels)
    rem = np.flatnonzero(lab == 0)[:15]
    with pytest.warns(RuntimeWarning, match="balance cannot be restored"):
        res, _, _ = eng.update(x, st, removed=rem)
    assert res.updated is False
    assert _counts_ok(res.labels, 6)


# ---------------------------------------------------------------------------
# The certificate rides updates (stats=True), and provenance is honest
# ---------------------------------------------------------------------------

def test_update_carries_certificate_when_stats():
    eng = AnticlusterEngine(k=8, plan=None, stats=True)
    x = jnp.asarray(_data(200, 5, seed=14))
    res0, st = eng.partition(x)
    assert res0.gap is not None and float(res0.gap) >= 0
    res, _, _ = eng.update(x, st, added=jnp.asarray(_data(10, 5, seed=15)))
    assert res.updated
    assert res.dual_bound is not None and res.gap is not None
    assert float(res.gap) >= 0
    # stats=False keeps the certificate (and its cost) off the result
    eng2 = AnticlusterEngine(k=8, plan=None, stats=False)
    _, st2 = eng2.partition(x)
    res2, _, _ = eng2.update(x, st2,
                             added=jnp.asarray(_data(10, 5, seed=15)))
    assert res2.dual_bound is None and res2.gap is None


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------

def test_update_guards():
    eng = AnticlusterEngine(k=4, plan=None)
    x = jnp.asarray(_data(64, 3, seed=16))
    _, st = eng.partition(x)
    with pytest.raises(TypeError, match="ABAState"):
        eng.update(x, {"prices": None})
    with pytest.raises(ValueError, match=r"added must be \(m, 3\)"):
        eng.update(x, st, added=np.ones((5, 7), np.float32))
    with pytest.raises(ValueError, match="must be unique"):
        eng.update(x, st, removed=np.array([1, 1, 2]))
    with pytest.raises(ValueError, match=r"in \[0, 64\)"):
        eng.update(x, st, removed=np.array([64]))
    with pytest.raises(ValueError, match="fewer than k"):
        eng.update(x, st, removed=np.arange(62))
    with pytest.raises(NotImplementedError, match="one group at a time"):
        eng.update(jnp.zeros((2, 64, 3)), st, added=np.ones((1, 3)))

    cat_eng = AnticlusterEngine(
        k=4, plan=None, categories=np.zeros(64, np.int32), n_categories=1)
    _, cat_st = cat_eng.partition(x)
    with pytest.raises(NotImplementedError, match="category-free"):
        cat_eng.update(x, cat_st, added=np.ones((2, 3), np.float32))


def test_update_requires_prev_labels():
    eng = AnticlusterEngine(k=4, plan=None)
    x = jnp.asarray(_data(64, 3, seed=17))
    _, st = eng.partition(x)
    stale = ABAState(prices=tuple(jnp.array(p) for p in st.prices),
                     moment_sum=jnp.array(st.moment_sum),
                     moment_count=jnp.array(st.moment_count),
                     prev_labels=jnp.full((64,), -1, jnp.int32))
    with pytest.raises(ValueError, match="prev_labels"):
        eng.update(x, stale, added=np.ones((2, 3), np.float32))


# ---------------------------------------------------------------------------
# IncrementalPartition: the object-level face
# ---------------------------------------------------------------------------

def test_incremental_partition_lifecycle():
    x0 = _data(128, 4, seed=18)
    part = IncrementalPartition(x0, k=8)
    assert part.n == len(part) == 128 and part.k == 8
    np.testing.assert_array_equal(
        np.asarray(part.labels),
        np.asarray(anticluster(jnp.asarray(x0), k=8).labels))

    res = part.update(added=_data(9, 4, seed=19))
    assert res.updated and part.n == 137
    assert res is part.result  # the wrapper stores what it returns
    assert _counts_ok(part.labels, 8)

    res2 = part.update(removed=np.arange(5))
    assert part.n == 132 and _counts_ok(part.labels, 8)
    assert res2.labels.shape == (132,)

    res3 = part.repartition()  # forcing a full warm re-solve still works
    assert _counts_ok(res3.labels, 8) and part.n == 132


def test_incremental_partition_engine_sharing_and_guards():
    eng = AnticlusterEngine(k=4, plan=None)
    a = IncrementalPartition(_data(64, 3, seed=20), engine=eng)
    b = IncrementalPartition(_data(64, 3, seed=21), engine=eng)
    assert eng.compile_count == 1  # both live partitions share the cache
    a.update(added=_data(3, 3, seed=22))
    assert a.n == 67 and b.n == 64  # deltas do not leak across partitions
    with pytest.raises(ValueError, match="not both"):
        IncrementalPartition(_data(64, 3), AnticlusterSpec(k=4), engine=eng)
