"""Batched auction engine: stack solves vs per-instance solves vs the exact
Hungarian oracle, masked/padded instances, and the fused matrix-free path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.aba import aba, aba_batched
from repro.core.assignment import (AuctionConfig, assignment_value,
                                   auction_solve, auction_solve_factored,
                                   scipy_solve)
from repro.core.hierarchical import hierarchical_aba
from repro.core.objective import balance_ok, objective_centroid


@pytest.mark.parametrize("B,n", [(1, 4), (5, 16), (3, 64), (16, 8)])
def test_batched_identical_to_independent(B, n, rng):
    """A (B, k, k) stack returns labels IDENTICAL to B independent solves."""
    cs = rng.normal(size=(B, n, n)).astype(np.float32)
    batched = np.asarray(auction_solve(jnp.asarray(cs)))
    singles = np.stack(
        [np.asarray(auction_solve(jnp.asarray(c))) for c in cs])
    np.testing.assert_array_equal(batched, singles)
    for a in batched:
        assert sorted(a) == list(range(n))


def test_batched_matches_hungarian_oracle(rng):
    """Every instance of the stack is within the eps-optimality bound."""
    B, n = 6, 32
    cs = rng.normal(size=(B, n, n)).astype(np.float32) * 10.0
    batched = np.asarray(auction_solve(jnp.asarray(cs)))
    eps = (cs.max() - cs.min()) / (AuctionConfig().eps_end_mul * n)
    for c, a in zip(cs, batched):
        va = assignment_value(c, a)
        vs = assignment_value(c, scipy_solve(c))
        assert va <= vs + 1e-3
        assert vs - va <= n * eps + 1e-2


def test_batched_masked_padded_instances(rng):
    """Instances with constant-cost dummy rows (the aba padding convention)
    still match their independent solves and stay permutations."""
    B, n = 5, 24
    cs = rng.normal(size=(B, n, n)).astype(np.float32)
    n_real = [24, 20, 24, 13, 1]
    for b, r in enumerate(n_real):
        cs[b, r:, :] = 0.0  # neutral dummy rows
    batched = np.asarray(auction_solve(jnp.asarray(cs)))
    singles = np.stack(
        [np.asarray(auction_solve(jnp.asarray(c))) for c in cs])
    np.testing.assert_array_equal(batched, singles)
    for c, a, r in zip(cs, batched, n_real):
        assert sorted(a) == list(range(n))
        # real rows still near the oracle on the padded matrix
        va = assignment_value(c, a)
        vs = assignment_value(c, scipy_solve(c))
        span = c.max() - c.min()
        assert vs - va <= n * span / (AuctionConfig().eps_end_mul * n) + 1e-2


def test_batched_fixed_rounds_identical(rng):
    cfg = AuctionConfig(fixed_rounds=96)
    cs = rng.normal(size=(4, 20, 20)).astype(np.float32)
    batched = np.asarray(auction_solve(jnp.asarray(cs), cfg))
    singles = np.stack(
        [np.asarray(auction_solve(jnp.asarray(c), cfg)) for c in cs])
    np.testing.assert_array_equal(batched, singles)


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 6), n=st.integers(2, 24), seed=st.integers(0, 100))
def test_batched_permutation_property(B, n, seed):
    cs = np.random.default_rng(seed).normal(size=(B, n, n)).astype(np.float32)
    out = np.asarray(auction_solve(jnp.asarray(cs)))
    for a in out:
        assert sorted(a) == list(range(n))


def test_batched_under_vmap(rng):
    """The batched-native engine stays vmap-safe (legacy calling pattern)."""
    cs = rng.normal(size=(6, 16, 16)).astype(np.float32)
    v = np.asarray(jax.vmap(auction_solve)(jnp.asarray(cs)))
    b = np.asarray(auction_solve(jnp.asarray(cs)))
    np.testing.assert_array_equal(v, b)


@pytest.mark.parametrize("force", ["pallas", "ref"])
def test_factored_fused_bidding(force, rng):
    """Matrix-free auction (fused bid_top2 round) vs the dense engine and
    the Hungarian oracle; 'pallas' exercises the interpret=True CPU path."""
    n, d = 32, 8
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    cost = -2.0 * x @ c.T + (c * c).sum(1)[None, :]
    af = np.asarray(auction_solve_factored(jnp.asarray(x), jnp.asarray(c),
                                           force=force))
    assert sorted(af) == list(range(n))
    vs = assignment_value(cost, scipy_solve(cost))
    span = cost.max() - cost.min()
    eps = span / (AuctionConfig().eps_end_mul * n)
    assert vs - assignment_value(cost, af) <= n * eps + 1e-2


def test_factored_fused_with_dummy_rows(rng):
    n, d, n_real = 24, 6, 17
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    is_real = np.arange(n) < n_real
    af = np.asarray(auction_solve_factored(
        jnp.asarray(x), jnp.asarray(c), is_real=jnp.asarray(is_real),
        force="pallas"))
    assert sorted(af) == list(range(n))
    cost = np.where(is_real[:, None],
                    -2.0 * x @ c.T + (c * c).sum(1)[None, :], 0.0)
    vs = assignment_value(cost, scipy_solve(cost))
    span = cost.max() - cost.min()
    assert vs - assignment_value(cost, af) <= span / 4.0 + 1e-2


def test_factored_stacked_matches_per_instance(rng):
    """A (G, n, d) factored stack (with per-group dummy rows) returns the
    same assignments as G independent factored solves."""
    G, n, d = 3, 18, 5
    x = rng.normal(size=(G, n, d)).astype(np.float32)
    c = rng.normal(size=(G, n, d)).astype(np.float32)
    ir = np.ones((G, n), bool)
    ir[1, 13:] = False
    ir[2, 5:] = False
    out = np.asarray(auction_solve_factored(
        jnp.asarray(x), jnp.asarray(c), is_real=jnp.asarray(ir)))
    singles = np.stack([
        np.asarray(auction_solve_factored(
            jnp.asarray(x[g]), jnp.asarray(c[g]), is_real=jnp.asarray(ir[g])))
        for g in range(G)])
    np.testing.assert_array_equal(out, singles)
    for a in out:
        assert sorted(a) == list(range(n))


def test_aba_fused_solver_quality(rng):
    x = rng.normal(size=(300, 5)).astype(np.float32)
    lf = np.asarray(aba(jnp.asarray(x), 6, solver="auction_fused"))
    ld = np.asarray(aba(jnp.asarray(x), 6))
    assert balance_ok(lf, 6)
    of = float(objective_centroid(jnp.asarray(x), jnp.asarray(lf), 6))
    od = float(objective_centroid(jnp.asarray(x), jnp.asarray(ld), 6))
    assert abs(of - od) / od < 5e-3


def test_aba_batched_matches_vmapped_aba(rng):
    G, M, D, k = 4, 40, 5, 5
    x = rng.normal(size=(G, M, D)).astype(np.float32)
    vm = np.zeros((G, M), bool)
    for g, v in enumerate([40, 39, 40, 37]):
        vm[g, :v] = True
    b = np.asarray(aba_batched(jnp.asarray(x), k, jnp.asarray(vm)))
    v = np.asarray(jax.vmap(
        lambda xx, m: aba(xx, k, valid_mask=m))(jnp.asarray(x),
                                                jnp.asarray(vm)))
    np.testing.assert_array_equal(np.where(vm, b, 0), np.where(vm, v, 0))
    for g in range(G):
        assert balance_ok(b[g][vm[g]], k, int(vm[g].sum()))


def test_hierarchical_batched_identical_to_vmapped(rng):
    x = rng.normal(size=(600, 6)).astype(np.float32)
    lb = np.asarray(hierarchical_aba(jnp.asarray(x), (4, 6)))
    lv = np.asarray(hierarchical_aba(jnp.asarray(x), (4, 6), batched=False))
    np.testing.assert_array_equal(lb, lv)
    assert balance_ok(lb, 24)
