"""Async serving tier: continuous batching, row-bucket padding parity,
deadline shedding and backpressure (deterministic fake clock -- no sleeps),
engine-error containment, the degraded hierarchical path, engine pools
across devices, the metrics snapshot, Spec.evolve, and per-call engine
masks."""

import threading
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.anticluster import AnticlusterEngine, AnticlusterSpec, anticluster
from repro.serve import (AnticlusterRouter, AnticlusterService, Rejected,
                         ServiceMetrics, Ticket)


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


class FakeClock:
    """Deterministic router clock: advances only when told to."""

    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


def _router(**kw):
    kw.setdefault("background", False)
    return AnticlusterRouter(**kw)


def _oneshot(x, **kw):
    return np.asarray(anticluster(jnp.asarray(x), **kw).labels)


# ---------------------------------------------------------------------------
# Parity: async submit+result == one-shot, including padded near-shapes
# ---------------------------------------------------------------------------

def test_submit_padded_near_shapes_match_oneshot_bitwise():
    # 100/97/110 rows all land in the 128 bucket and share ONE padded
    # stacked call; every label vector must equal its unpadded one-shot
    r = _router(k=5, plan=None)
    xs = [_data(n, 4, seed=n) for n in (100, 97, 110)]
    tickets = [r.submit(x) for x in xs]
    for t, x in zip(tickets, xs):
        res = t.result()
        assert res.labels.shape == (x.shape[0],)
        np.testing.assert_array_equal(np.asarray(res.labels),
                                      _oneshot(x, k=5, plan=None))
    m = r.metrics()
    assert m.stacked_calls == 1 and m.completed == 3
    assert ("stack", (128, 4), 4) in r._lanes
    assert 0.0 < m.row_occupancy < 1.0  # padded rows are accounted


def test_sync_wrappers_match_async_path_bitwise():
    xs = [_data(n, 3, seed=n) for n in (80, 70, 80, 64)]
    svc = AnticlusterService(k=4, plan=None)
    sync = svc.partition_many(xs)
    r = _router(k=4, plan=None)
    tickets = [r.submit(x) for x in xs]
    for s, t in zip(sync, tickets):
        np.testing.assert_array_equal(np.asarray(s.labels),
                                      np.asarray(t.result().labels))
    # partition == submit().result() on yet another fresh router
    r2 = _router(k=4, plan=None)
    np.testing.assert_array_equal(np.asarray(r2.partition(xs[0]).labels),
                                  np.asarray(sync[0].labels))


def test_interleave_regime_is_never_padded():
    # n // k <= 8 solves through the interleave rearrangement, which the
    # masked core skips -- those requests must stack at exact shape only
    r = _router(k=8, plan=None)
    xs = [_data(40, 4, seed=s) for s in (1, 2)]
    t1, t2 = r.submit(xs[0]), r.submit(xs[1])
    for t, x in zip((t1, t2), xs):
        np.testing.assert_array_equal(np.asarray(t.result().labels),
                                      _oneshot(x, k=8, plan=None))
    assert ("stack", (40, 4), 2) in r._lanes  # 40 not padded to 64
    # a 48-row neighbour cannot share that lane
    t3 = r.submit(_data(48, 4, seed=3))
    t3.result()
    assert ("stack", (40, 4), 2) in r._lanes and r.lane_count == 2


def test_exact_fit_singleton_takes_solo_lane():
    r = _router(k=5, plan=None)
    x = _data(128, 4, seed=9)  # pow2 rows: nothing to pad
    np.testing.assert_array_equal(np.asarray(r.submit(x).result().labels),
                                  _oneshot(x, k=5, plan=None))
    assert ("solo", (128, 4)) in r._lanes and r.lane_count == 1


# ---------------------------------------------------------------------------
# Lane lifecycle under the queue
# ---------------------------------------------------------------------------

def test_row_bucket_growth_and_shrink():
    r = _router(k=5, plan=None)
    # growth: 100/120 share bucket 128, 200 opens bucket 256
    ts = [r.submit(_data(n, 3, seed=n)) for n in (100, 120, 200)]
    for t in ts:
        t.result()
    assert ("stack", (128, 3), 2) in r._lanes
    assert ("stack", (256, 3), 1) in r._lanes
    assert r.lane_count == 2
    # shrink: later sparse traffic in a known bucket opens a narrower
    # group lane but reuses the engine pool (no relearning of buckets)
    r.submit(_data(110, 3, seed=7)).result()
    assert ("stack", (128, 3), 1) in r._lanes and r.lane_count == 3
    # ...and a repeat burst warm-hits the wide lane instead of growing
    before = r.lane_count
    ts = [r.submit(_data(n, 3, seed=n + 50)) for n in (100, 120)]
    for t in ts:
        t.result()
    assert r.lane_count == before
    assert r.metrics().warm_calls >= 1


def test_max_group_splits_oversized_bursts():
    r = _router(k=4, plan=None, max_group=2)
    xs = [_data(100, 3, seed=s) for s in range(5)]
    outs = r.partition_many(xs)
    # the first group and the (separate-lane) remainder solve cold ->
    # bitwise one-shot parity; the second group warm-starts from the first
    # group's prices (eps-optimal drift allowed, balance exact)
    for i in (0, 1, 4):
        np.testing.assert_array_equal(np.asarray(outs[i].labels),
                                      _oneshot(xs[i], k=4, plan=None))
    assert all(o.balanced for o in outs)
    m = r.metrics()
    assert m.stacked_calls == 3  # 2 + 2 + 1 under max_group=2
    assert ("stack", (128, 3), 2) in r._lanes
    assert ("stack", (128, 3), 1) in r._lanes


def test_mixed_cold_warm_burst_counters():
    r = _router(k=5, plan=None)
    xs = [_data(96, 3, seed=s) for s in (0, 1)]
    r.partition_many(xs)                      # cold: compiles the 2-lane
    r.partition_many(xs)                      # warm: same lane, same shapes
    m = r.metrics()
    assert m.cold_calls == 1 and m.warm_calls == 1
    assert m.warm_hit_rate == 0.5
    # a new signature mid-stream is cold without disturbing the warm lane
    r.submit(_data(200, 3, seed=9)).result()
    m = r.metrics()
    assert m.cold_calls == 2 and m.warm_calls == 1
    lane = r._lanes[("stack", (128, 3), 2)]
    assert lane.engine.compile_count == 1     # warm reuse never retraced


# ---------------------------------------------------------------------------
# Deadlines, backpressure, shutdown (fake clock -- no sleeps)
# ---------------------------------------------------------------------------

def test_deadline_shedding_with_fake_clock():
    clock = FakeClock()
    r = _router(k=4, plan=None, clock=clock)
    keep = r.submit(_data(64, 3, seed=1))
    shed = r.submit(_data(64, 3, seed=2), deadline=5.0)
    clock.advance(10.0)                       # expire before any serving
    r.drain()
    assert keep.done() and shed.done()
    assert keep.rejection is None
    assert shed.rejection is not None and shed.rejection.reason == "deadline"
    with pytest.raises(Rejected, match="deadline"):
        shed.result()
    m = r.metrics()
    assert m.shed_deadline == 1 and m.completed == 1
    assert 0.0 < m.shed_rate < 1.0
    # latency stamps come from the router clock
    assert keep.latency == 10.0 and shed.latency == 10.0


def test_deadline_not_yet_expired_is_served():
    clock = FakeClock()
    r = _router(k=4, plan=None, clock=clock)
    t = r.submit(_data(64, 3, seed=3), deadline=5.0)
    clock.advance(4.0)
    assert t.result().labels.shape == (64,)
    assert r.metrics().shed_deadline == 0


def test_queue_full_backpressure():
    r = _router(k=4, plan=None, max_queue=2)
    x = _data(64, 3, seed=1)
    t1, t2 = r.submit(x), r.submit(x)
    with pytest.raises(Rejected, match="queue_full") as ei:
        r.submit(x)
    assert ei.value.reason == "queue_full"
    # an atomic burst larger than the remaining room is rejected whole,
    # and EVERY request it carried counts toward rejected_full
    with pytest.raises(Rejected, match="queue_full"):
        r.partition_many([x])
    with pytest.raises(Rejected, match="queue_full"):
        r.partition_many([x, x])
    assert r.metrics().rejected_full == 4
    r.drain()                                 # queue drains -> room again
    assert t1.done() and t2.done()
    assert r.submit(x).result().labels.shape == (64,)


def test_close_rejects_pending_and_new_requests():
    r = _router(k=4, plan=None)
    t = r.submit(_data(64, 3, seed=1))
    r.close()
    assert t.rejection is not None and t.rejection.reason == "shutdown"
    with pytest.raises(Rejected, match="shutdown"):
        r.submit(_data(64, 3, seed=2))


def test_router_is_a_context_manager():
    with _router(k=4, plan=None) as r:
        t = r.submit(_data(64, 3, seed=1))
        t.result()
    with pytest.raises(Rejected, match="shutdown"):
        r.submit(_data(64, 3, seed=1))


# ---------------------------------------------------------------------------
# Engine errors resolve tickets and never kill the serving loop
# ---------------------------------------------------------------------------

def test_engine_error_resolves_every_ticket_in_the_group(monkeypatch):
    r = _router(k=4, plan=None)
    t1 = r.submit(_data(64, 3, seed=1))
    t2 = r.submit(_data(64, 3, seed=2))   # same bucket: one popped group
    real = AnticlusterEngine.repartition

    def boom(self, *a, **kw):
        raise RuntimeError("lane exploded")

    monkeypatch.setattr(AnticlusterEngine, "repartition", boom)
    with pytest.raises(RuntimeError, match="lane exploded"):
        t1.result()
    # the whole popped group resolved -- nobody hangs on a lost request
    assert t1.done() and t2.done()
    assert t1.rejection is None and isinstance(t1.error, RuntimeError)
    with pytest.raises(RuntimeError, match="lane exploded"):
        t2.result()
    m = r.metrics()
    assert m.errored == 2 and m.completed == 0
    # the router keeps serving once the engine behaves again
    monkeypatch.setattr(AnticlusterEngine, "repartition", real)
    res = r.submit(_data(64, 3, seed=3)).result()
    assert res.labels.shape == (64,)
    assert r.metrics().completed == 1


def test_background_worker_survives_engine_error(monkeypatch):
    real = AnticlusterEngine.repartition

    def boom(self, *a, **kw):
        raise RuntimeError("lane exploded")

    monkeypatch.setattr(AnticlusterEngine, "repartition", boom)
    with AnticlusterRouter(k=4, plan=None) as r:
        t = r.submit(_data(64, 3, seed=1))
        with pytest.raises(RuntimeError, match="lane exploded"):
            t.result(timeout=300)          # worker resolves, not hangs
        monkeypatch.setattr(AnticlusterEngine, "repartition", real)
        t2 = r.submit(_data(64, 3, seed=2))
        assert t2.result(timeout=300).labels.shape == (64,)
        m = r.metrics()
        assert m.errored == 1 and m.completed == 1


def test_submit_restarts_a_dead_worker():
    with AnticlusterRouter(k=4, plan=None) as r:
        r.submit(_data(64, 3, seed=1)).result(timeout=300)
        dead = threading.Thread(target=lambda: None)
        dead.start()
        dead.join()
        r._worker = dead                   # simulate a crashed worker
        t = r.submit(_data(64, 3, seed=2))
        assert r._worker is not dead       # submit spawned a fresh one
        assert t.result(timeout=300).labels.shape == (64,)


def test_inline_timeout_checked_before_stepping():
    r = _router(k=4, plan=None)
    t = r.submit(_data(64, 3, seed=1))
    with pytest.raises(TimeoutError):
        t.result(timeout=0)                # zero budget: no step started
    assert not t.done()
    assert t.result().labels.shape == (64,)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for a sharded mesh")
def test_mesh_indivisible_rows_autopad_or_rejected():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("data",))
    # flat per-shard plan: uneven rows ride the engine's auto-pad (masked
    # zero rows behind a per-call valid_mask) instead of being rejected
    r = _router(k=4, mesh=mesh, data_axes=("data",))
    res = r.submit(_data(65, 3, seed=1)).result()
    assert res.labels.shape == (65,)
    counts = np.bincount(np.asarray(res.labels), minlength=4)
    assert counts.min() >= 65 // 4 and counts.max() <= -(-65 // 4)

    # hierarchical per-shard plan is the one composition the engine cannot
    # mask; still rejected synchronously at submit, not inside a lane
    r2 = _router(k=8, mesh=mesh, data_axes=("data",), plan=(2, 4))
    with pytest.raises(ValueError, match="shard count"):
        r2.submit(_data(65, 3, seed=1))
    assert r2.metrics().queue_depth == 0


# ---------------------------------------------------------------------------
# Degraded paths are loud
# ---------------------------------------------------------------------------

def test_hierarchical_burst_degrades_loudly_once():
    r = _router(k=6, plan=(2, 3))
    xs = [_data(120, 3, seed=s) for s in (0, 1)]
    with pytest.warns(RuntimeWarning, match="sequential"):
        outs = r.partition_many(xs)
    # first request is cold -> bitwise parity; the second warm-starts on
    # the same solo lane (eps-optimal drift allowed)
    np.testing.assert_array_equal(np.asarray(outs[0].labels),
                                  _oneshot(xs[0], k=6, plan=(2, 3)))
    assert all(o.balanced for o in outs)
    assert r.metrics().degraded_sequential == 2
    # the warning fires once; the counter keeps counting
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        r.partition_many(xs)
    assert r.metrics().degraded_sequential == 4
    assert ("solo", (120, 3)) in r._lanes     # served on one warm solo lane


def test_admission_guards():
    r = _router(k=4, plan=None)
    with pytest.raises(ValueError, match=r"\(n, d\)"):
        r.submit(_data(64, 3, seed=1)[None])
    with pytest.raises(ValueError, match="rows"):
        r.submit(_data(2, 3, seed=1))
    with pytest.raises(NotImplementedError, match="per-dataset"):
        AnticlusterRouter(k=4, valid_mask=np.ones(10, bool))
    with pytest.raises(ValueError, match="max_queue"):
        AnticlusterRouter(k=4, max_queue=0)


# ---------------------------------------------------------------------------
# Ticket API + background worker
# ---------------------------------------------------------------------------

def test_ticket_states_and_timestamps():
    clock = FakeClock()
    r = _router(k=4, plan=None, clock=clock)
    t = r.submit(_data(64, 3, seed=1))
    assert isinstance(t, Ticket)
    assert not t.done() and t.latency is None and t.rejection is None
    clock.advance(2.5)
    t.result()
    assert t.done() and t.latency == 2.5 and t.completed_at == 2.5


def test_background_worker_round_trip():
    x = _data(100, 4, seed=11)
    with AnticlusterRouter(k=5, plan=None) as r:
        t = r.submit(x)
        labels = np.asarray(t.result(timeout=300).labels)
        assert t.done()
    np.testing.assert_array_equal(labels, _oneshot(x, k=5, plan=None))


# ---------------------------------------------------------------------------
# Engine pools across devices
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for round-robin placement")
def test_engine_pool_places_lanes_round_robin():
    r = _router(k=4, plan=None, row_buckets=False)
    xs = [_data(n, 3, seed=n) for n in (64, 96)]  # two lanes, no sharing
    for x in xs:
        np.testing.assert_array_equal(
            np.asarray(r.submit(x).result().labels),
            _oneshot(x, k=4, plan=None))
    devices = [lane.device for lane in r._lanes.values()]
    assert None not in devices
    assert len({d.id for d in devices}) == 2  # successive lanes alternate
    assert r.metrics().devices >= 2


def test_metrics_snapshot_schema():
    r = _router(k=4, plan=None)
    r.partition_many([_data(64, 3, seed=s) for s in (0, 1)])
    m = r.metrics()
    assert isinstance(m, ServiceMetrics)
    assert m.queue_depth == 0 and m.submitted == 2 and m.completed == 2
    assert m.stack_occupancy == 1.0           # 2 requests filled a 2-bucket
    assert m.shed_rate == 0.0 and m.errored == 0
    assert list(m.lane_compile_counts.values()) == [1]
    assert m.devices == len(jax.devices())


# ---------------------------------------------------------------------------
# AnticlusterSpec.evolve
# ---------------------------------------------------------------------------

def test_evolve_applies_and_revalidates():
    spec = AnticlusterSpec(k=6, plan=(2, 3))
    ev = spec.evolve(k=8, plan=None)
    assert ev.k == 8 and ev.plan is None and spec.k == 6
    assert spec.evolve() is spec              # no changes -> same object
    with pytest.raises(ValueError, match="prod"):
        spec.evolve(k=7)                      # __post_init__ re-runs
    with pytest.raises(TypeError, match="n_clusters"):
        spec.evolve(n_clusters=4)             # unknown field named back
    # every overrides surface routes through evolve (specs compare by
    # identity -- eq=False -- so check the evolved fields)
    eng = AnticlusterEngine(spec, k=8, plan=None)
    assert eng.spec.k == 8 and eng.spec.plan is None
    svc = AnticlusterService(spec, k=8, plan=None)
    assert svc.spec.k == 8 and svc.spec.plan is None


# ---------------------------------------------------------------------------
# Engine per-call valid_mask (the primitive the row buckets lean on)
# ---------------------------------------------------------------------------

def test_engine_per_call_mask_matches_unpadded_bitwise():
    x = _data(100, 4, seed=21)
    pad = np.concatenate([x, np.zeros((28, 4), np.float32)])
    mask = np.arange(128) < 100
    eng = AnticlusterEngine(k=5, plan=None)
    res, state = eng.partition(pad, valid_mask=mask)
    np.testing.assert_array_equal(np.asarray(res.labels[:100]),
                                  _oneshot(x, k=5, plan=None))
    # a differently-padded same-shape call reuses the SAME executable
    y = _data(90, 4, seed=22)
    pady = np.concatenate([y, np.zeros((38, 4), np.float32)])
    res2, _ = eng.repartition(pady, state, valid_mask=np.arange(128) < 90)
    assert res2.labels.shape == (128,)
    assert eng.compile_count == 1


def test_engine_per_call_mask_guards():
    eng = AnticlusterEngine(k=4, plan=None)
    x = _data(64, 3, seed=1)
    with pytest.raises(ValueError, match="does not match"):
        eng.partition(x, valid_mask=np.ones(32, bool))
    masked_spec = AnticlusterSpec(k=4, plan=None,
                                  valid_mask=np.ones(64, bool))
    with pytest.raises(ValueError, match="mutually exclusive"):
        AnticlusterEngine(masked_spec).partition(
            x, valid_mask=np.ones(64, bool))


# ---------------------------------------------------------------------------
# Live partitions: the update lane
# ---------------------------------------------------------------------------

def test_live_partition_open_update_close():
    r = _router(k=4, update_threshold=0.25)
    x = _data(64, 3, seed=7)
    res = r.open_partition("live", x).result()
    assert res.labels.shape == (64,)

    # in-threshold delta takes the update path and keeps balance
    res2 = r.submit_update("live", added=_data(4, 3, seed=8)).result()
    assert res2.updated
    labels = r.partition_labels("live")
    assert labels.shape == (68,)
    counts = np.bincount(labels, minlength=4)
    assert counts.min() >= 68 // 4 and counts.max() <= -(-68 // 4)

    # over-threshold delta falls back loudly; the router counts it
    with pytest.warns(RuntimeWarning, match="full warm repartition"):
        res3 = r.submit_update("live", added=_data(40, 3, seed=9)).result()
    assert res3.updated is False
    m = r.metrics()
    assert m.update_calls == 2 and m.update_fallbacks == 1
    assert m.update_fallback_rate == 0.5 and m.live_partitions == 1

    assert r.live_partition("live").n == 108
    r.close_partition("live")
    assert r.metrics().live_partitions == 0
    with pytest.raises(ValueError, match="not open"):
        r.submit_update("live", added=_data(4, 3, seed=8))


def test_live_partition_guards():
    r = _router(k=4)
    r.open_partition("dup", _data(64, 3, seed=1)).result()
    with pytest.raises(ValueError, match="already open"):
        r.open_partition("dup", _data(64, 3, seed=2))
    with pytest.raises(ValueError, match="not open"):
        r.submit_update("missing", added=_data(4, 3, seed=3))
    with pytest.raises(KeyError):
        r.live_partition("missing")
    # a failed open must not reserve the name
    with pytest.raises(ValueError, match="rows"):
        r.open_partition("tiny", _data(2, 3, seed=4))
    r.open_partition("tiny", _data(64, 3, seed=5)).result()
    assert r.metrics().live_partitions == 2


# ---------------------------------------------------------------------------
# Latency / queue-wait percentiles (obs histograms, fake clock -- no sleeps)
# ---------------------------------------------------------------------------

def test_latency_percentiles_fake_clock():
    clock = FakeClock()
    r = _router(k=4, plan=None, clock=clock)
    m = r.metrics()                           # before any request: all 0.0
    assert m.latency_p50 == m.latency_p99 == 0.0
    assert m.queue_wait_p50 == m.queue_wait_p99 == 0.0

    r.submit(_data(64, 3, seed=1))
    clock.advance(0.25)                       # queued for exactly 0.25 s
    r.drain()                                 # clock frozen while serving
    m = r.metrics()
    assert m.latency_p50 == m.latency_p99 == 0.25
    assert m.queue_wait_p50 == m.queue_wait_p99 == 0.25

    r.submit(_data(64, 3, seed=2))
    clock.advance(0.5)                        # second sample: 0.5 s
    r.drain()
    m = r.metrics()
    # nearest-rank over [0.25, 0.5]: p50 is the first sample, p99 the last
    assert m.latency_p50 == 0.25 and m.latency_p99 == 0.5
    assert m.queue_wait_p50 == 0.25 and m.queue_wait_p99 == 0.5
    # shed requests never pollute the served-latency reservoir
    shed = r.submit(_data(64, 3, seed=3), deadline=1.0)
    clock.advance(10.0)
    r.drain()
    assert shed.rejection is not None
    assert r.metrics().latency_p99 == 0.5
