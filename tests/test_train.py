"""Training substrate: optimizer math, convergence, checkpoint roundtrip."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.registry import get_config
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, lr_at
from repro.train.train_step import make_train_step


def test_lr_schedule():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                    min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) < float(lr_at(cfg, 9))
    assert abs(float(lr_at(cfg, 10)) - 1e-3) < 1e-5
    assert abs(float(lr_at(cfg, 1000)) - 1e-4) < 1e-6


def test_adamw_matches_manual():
    cfg = OptConfig(lr=0.1, warmup_steps=0, decay_steps=1, min_lr_frac=1.0,
                    weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.asarray([[1.0, 2.0]])}
    g = {"w": jnp.asarray([[0.5, -0.5]])}
    st = adamw_init(p)
    p2, st2, _ = adamw_update(cfg, g, st, p)
    m = 0.1 * 0.5
    v = 0.05 * 0.25
    up = (m / 0.1) / (np.sqrt(v / 0.05) + cfg.eps)
    np.testing.assert_allclose(np.asarray(p2["w"])[0, 0], 1.0 - 0.1 * up,
                               rtol=1e-5)
    assert int(st2["step"]) == 1


def test_grad_clip():
    cfg = OptConfig(lr=1.0, warmup_steps=0, decay_steps=1, min_lr_frac=1.0,
                    weight_decay=0.0, grad_clip=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(cfg, g, adamw_init(p), p)
    assert float(m["grad_norm"]) > 1.0  # reported unclipped


def test_loss_decreases(one_device_mesh):
    cfg = get_config("smollm-360m", reduced=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    tokens = jax.random.randint(key, (64, 32), 0, cfg.vocab_size)
    step = jax.jit(make_train_step(
        cfg, one_device_mesh,
        OptConfig(lr=3e-3, warmup_steps=2, decay_steps=30), loss_chunk=8))
    losses = []
    for i in range(15):
        batch = {"tokens": tokens[(i % 4) * 16:(i % 4 + 1) * 16]}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_microbatch_accumulation_equivalence(one_device_mesh):
    """grad accumulation over microbatches == one big batch (same loss/update
    direction within fp tolerance)."""
    cfg = get_config("smollm-360m", reduced=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, decay_steps=10, grad_clip=0.0)
    s1 = jax.jit(make_train_step(cfg, one_device_mesh, ocfg, microbatches=1,
                                 loss_chunk=8))
    s2 = jax.jit(make_train_step(cfg, one_device_mesh, ocfg, microbatches=4,
                                 loss_chunk=8))
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p2, _, m2 = s2(params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-3)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("smollm-360m", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    state = {"params": params, "opt": opt}
    ckpt.save(str(tmp_path), 7, state)
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"w": jnp.arange(4.0) + s}, keep=2)
    steps = ckpt.latest_steps(str(tmp_path))
    assert sorted(steps) == [4, 5]
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(4.0) + 5)


def test_checkpoint_resharding(tmp_path, one_device_mesh):
    """Restore with explicit shardings (elastic restart path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.ones((8, 4))}
    ckpt.save(str(tmp_path), 1, tree)
    sh = {"w": NamedSharding(one_device_mesh, P("data", None))}
    restored, _ = ckpt.restore(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
