"""``hypothesis`` import with a deterministic fallback mini-runner.

CI installs real hypothesis (see requirements.txt) and gets full
property-based search.  Environments without it (the bare seed container)
still collect and run every property test: the fallback draws a fixed,
seed-deterministic sample of examples per test instead of erroring at
import.  Only the tiny strategy surface this suite uses is implemented.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def example(self, rnd):
            return rnd.randint(self.lo, self.hi)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NB: no functools.wraps -- pytest must see a zero-arg signature,
            # not the original one (it would treat drawn params as fixtures).
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for i in range(n):
                    rnd = random.Random(0xABA0 + i)
                    drawn = {k: s.example(rnd) for k, s in strategies.items()}
                    fn(*args, **{**kwargs, **drawn})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
