"""K-plus augmentation: balancing variances across anticlusters
(paper Section 3.3 research gap, via Papenberg 2024)."""

import numpy as np
import jax.numpy as jnp

from repro.core import aba
from repro.core.kplus import kplus_augment, moment_spread


def test_kplus_balances_variance():
    rng = np.random.default_rng(0)
    # heteroscedastic data: variance varies strongly along a latent factor
    scale = np.exp(rng.normal(size=(500, 1)))
    x = (rng.normal(size=(500, 6)) * scale).astype(np.float32)
    k = 5
    l_plain = np.asarray(aba(jnp.asarray(x), k))
    l_kplus = np.asarray(aba(jnp.asarray(kplus_augment(x, 2)), k))
    s_plain = moment_spread(x, l_plain, k, 2)
    s_kplus = moment_spread(x, l_kplus, k, 2)
    assert s_kplus < s_plain  # variances strictly better balanced
    # means stay balanced too (first-moment spread not blown up)
    m_plain = moment_spread(x, l_plain, k, 1)
    m_kplus = moment_spread(x, l_kplus, k, 1)
    assert m_kplus < 10 * max(m_plain, 1e-6)


def test_kplus_shapes():
    x = np.random.default_rng(1).normal(size=(50, 4))
    assert kplus_augment(x, 2).shape == (50, 8)
    assert kplus_augment(x, 3).shape == (50, 12)
