"""Per-arch smoke tests (reduced same-family configs, Section f of the
assignment): one forward + one train step on CPU, asserting shapes + no
NaNs; prefill/decode consistency with the training forward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.registry import ARCHS, get_config
from repro.models import transformer as T
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import make_train_step


def _batch(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    kw = {}
    if cfg.frontend == "vision":
        batch["extra_embeds"] = kw["extra_embeds"] = jax.random.normal(
            key, (b, 8, cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(s)[None, :, None],
                               (b, s, 3)).astype(jnp.int32)
        batch["positions"] = kw["positions"] = pos
    if cfg.enc_layers:
        batch["enc_frames"] = kw["enc_frames"] = jax.random.normal(
            key, (b, cfg.enc_ctx, cfg.d_model))
    return batch, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, one_device_mesh):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch, kw = _batch(cfg, key)
    b, s = batch["tokens"].shape

    logits = T.forward(cfg, params, batch["tokens"], mesh=one_device_mesh,
                       **kw)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())

    step = make_train_step(cfg, one_device_mesh, OptConfig(lr=1e-3),
                           loss_chunk=8)
    params2, opt2, metrics = jax.jit(step)(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = max(float(jnp.abs(a - b2).max()) for a, b2 in zip(
        jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma2-2b",
                                  "deepseek-v2-236b", "jamba-v0.1-52b",
                                  "falcon-mamba-7b", "whisper-medium",
                                  "qwen2-vl-7b"])
def test_prefill_decode_consistency(arch, one_device_mesh):
    """prefill last-token logits == forward last-token logits; then one
    decode step matches a re-run of forward on the extended sequence."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    batch, kw = _batch(cfg, key)
    tokens = batch["tokens"]
    b, s = tokens.shape
    logits = T.forward(cfg, params, tokens, mesh=one_device_mesh, **kw)
    pkw = dict(kw)
    lp, cache = T.prefill(cfg, params, tokens, max_len=s + 4,
                          mesh=one_device_mesh, **pkw)
    np.testing.assert_allclose(np.asarray(lp[:, 0, :cfg.vocab_size]),
                               np.asarray(logits[:, -1, :cfg.vocab_size]),
                               rtol=2e-2, atol=3e-2)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    l2, _ = T.decode_step(cfg, params, cache, jnp.int32(s), nxt,
                          mesh=one_device_mesh)
    # reference: full forward on the extended sequence
    ext = jnp.concatenate([tokens, nxt], axis=1)
    kw2 = dict(kw)
    if cfg.mrope_sections:
        kw2["positions"] = jnp.broadcast_to(
            jnp.arange(s + 1)[None, :, None], (b, s + 1, 3)).astype(jnp.int32)
    lref = T.forward(cfg, params, ext, mesh=one_device_mesh, **kw2)
    np.testing.assert_allclose(np.asarray(l2[:, 0, :cfg.vocab_size]),
                               np.asarray(lref[:, -1, :cfg.vocab_size]),
                               rtol=3e-2, atol=5e-2)


def test_flash_attention_exact():
    """Blockwise attention == full softmax attention (exactness)."""
    from repro.models.layers import flash_attention
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 96, 6, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    out = flash_attention(q, k, v, causal=True, chunk_q=32, chunk_kv=16)
    # reference
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgqs,bskh->bqkgh", p, v).reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    # sliding window
    outw = flash_attention(q, k, v, causal=True, window=24, chunk_q=32,
                           chunk_kv=16)
    pos = jnp.arange(s)
    maskw = mask & (pos[None, :] > pos[:, None] - 24)
    scoresw = jnp.where(maskw[None, None, None],
                        jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(hd),
                        -1e30)
    pw = jax.nn.softmax(scoresw, axis=-1)
    refw = jnp.einsum("bkgqs,bskh->bqkgh", pw, v).reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(refw),
                               rtol=2e-3, atol=2e-3)


def test_vocab_padding_masked():
    cfg = get_config("granite-moe-3b-a800m", reduced=True, vocab_size=251)
    assert cfg.padded_vocab == 256
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 251)
    logits = T.forward(cfg, params, tokens)
    assert bool((logits[..., 251:] < -1e29).all())


def test_mamba_chunked_scan_equivalence():
    """S`Perf A: chunked SSM scan must be numerically identical."""
    import dataclasses
    cfg1 = get_config("falcon-mamba-7b", reduced=True)
    cfg2 = dataclasses.replace(
        cfg1, ssm=dataclasses.replace(cfg1.ssm, scan_chunk=8))
    params = T.init_params(cfg1, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg1.vocab_size)
    l1 = np.asarray(T.forward(cfg1, params, tokens))
    l2 = np.asarray(T.forward(cfg2, params, tokens))
    np.testing.assert_allclose(l1, l2, atol=1e-4)
    g1 = jax.grad(lambda p: T.lm_loss(cfg1, p, {"tokens": tokens},
                                      loss_chunk=8))(params)
    g2 = jax.grad(lambda p: T.lm_loss(cfg2, p, {"tokens": tokens},
                                      loss_chunk=8))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_embed_shard_dmodel_equivalence():
    """S`Perf B: the collective-free embedding sharding is math-identical."""
    cfg1 = get_config("qwen2.5-14b", reduced=True)
    cfg2 = get_config("qwen2.5-14b", reduced=True, embed_shard="dmodel")
    params = T.init_params(cfg1, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg1.vocab_size)
    l1 = np.asarray(T.forward(cfg1, params, tokens))
    l2 = np.asarray(T.forward(cfg2, params, tokens))
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_seq_parallel_equivalence(one_device_mesh):
    """S`Perf B6: sequence-parallel residual stream is math-identical."""
    cfg1 = get_config("smollm-360m", reduced=True)
    cfg2 = get_config("smollm-360m", reduced=True, seq_parallel=True)
    params = T.init_params(cfg1, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg1.vocab_size)
    l1 = np.asarray(T.forward(cfg1, params, tokens, mesh=one_device_mesh))
    l2 = np.asarray(T.forward(cfg2, params, tokens, mesh=one_device_mesh))
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_sliding_window_decode_matches_forward(one_device_mesh):
    """gemma2-style local attention: decode with a BINDING window must match
    the training forward at the same position (regression for the
    attend_one window mask)."""
    import dataclasses
    from repro.models.config import LayerSpec
    cfg = get_config("gemma2-2b", reduced=True)
    # make every layer local with a window smaller than the sequence
    pat = tuple(LayerSpec(mixer="attn", mlp="dense", sliding_window=8)
                for _ in cfg.pattern)
    cfg = dataclasses.replace(cfg, pattern=pat)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg.vocab_size)
    logits = T.forward(cfg, params, tokens, mesh=one_device_mesh)
    lp, cache = T.prefill(cfg, params, tokens, max_len=26,
                          mesh=one_device_mesh)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    l2, _ = T.decode_step(cfg, params, cache, jnp.int32(24), nxt,
                          mesh=one_device_mesh)
    ext = jnp.concatenate([tokens, nxt], axis=1)
    lref = T.forward(cfg, params, ext, mesh=one_device_mesh)
    np.testing.assert_allclose(np.asarray(l2[:, 0, :cfg.vocab_size]),
                               np.asarray(lref[:, -1, :cfg.vocab_size]),
                               rtol=3e-2, atol=5e-2)
