"""Distributed engine sessions: the mesh as an orthogonal placement axis.

One-device tests run in tier-1 (a 1-device mesh exercises the whole
``shard_map`` machinery without multi-device semantics); the tests marked
``_multi`` need two devices and are exercised by the CI mesh smoke job
(``XLA_FLAGS=--xla_force_host_platform_device_count=2``) -- under tier-1's
single device they skip.
"""

import pickle

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.anticluster import (ABAState, AnticlusterEngine, AnticlusterSpec,
                               ShardedABAState, anticluster)
from repro.core.objective import balance_ok, objective_centroid

_multi = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _mesh1():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def _mesh2():
    return Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("data",))


# ---------------------------------------------------------------------------
# Cold parity + the zeroed-sharded-state sentinel (1-device mesh)
# ---------------------------------------------------------------------------

def test_mesh_engine_cold_parity_and_sentinel():
    x = jnp.asarray(_data(128, 5, 50))
    spec = AnticlusterSpec(k=8, mesh=_mesh1(), data_axes=("data",))
    one = anticluster(x, spec)
    eng = AnticlusterEngine(spec)
    res, state = eng.partition(x)
    assert isinstance(state, ShardedABAState)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(one.labels))
    assert res.plan == one.plan
    # zeroed ShardedABAState IS the cold start, bit for bit
    res0, _ = eng.repartition(x, eng.init_state(x))
    np.testing.assert_array_equal(np.asarray(res0.labels),
                                  np.asarray(one.labels))
    assert eng.compile_count == 1
    np.testing.assert_array_equal(np.asarray(state.prev_labels),
                                  np.asarray(res.labels))


def test_mesh_engine_warm_quality_and_compile_count():
    rng = np.random.default_rng(51)
    x = _data(192, 6, 51)
    spec = AnticlusterSpec(k=12, mesh=_mesh1(), data_axes=("data",))
    eng = AnticlusterEngine(spec)
    _res, state = eng.partition(jnp.asarray(x))
    for _ in range(3):
        x = x + rng.normal(size=x.shape).astype(np.float32) * 0.05
        xj = jnp.asarray(x)
        res, state = eng.repartition(xj, state)
        assert res.balanced and balance_ok(np.asarray(res.labels), 12, 192)
        o_warm = float(objective_centroid(xj, res.labels, 12))
        o_ref = float(objective_centroid(xj, anticluster(xj, spec).labels, 12))
        assert abs(o_warm - o_ref) / abs(o_ref) < 0.01
    assert eng.compile_count == 1
    assert any(bool(np.any(np.asarray(p) != 0)) for p in state.prices)


# ---------------------------------------------------------------------------
# Mesh x categories / valid_mask / streaming (the lifted restrictions)
# ---------------------------------------------------------------------------

def test_mesh_categories_parity_single_shard():
    rng = np.random.default_rng(52)
    x = jnp.asarray(_data(120, 4, 52))
    cats = rng.integers(0, 3, size=120).astype(np.int32)
    spec = AnticlusterSpec(k=6, mesh=_mesh1(), data_axes=("data",),
                           categories=cats)
    res = anticluster(x, spec)
    # one shard: the mesh path must equal the local auto-plan path exactly
    ref = anticluster(x, AnticlusterSpec(k=6, categories=cats))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(ref.labels))
    # and the engine agrees bit for bit, warm lane included
    eng = AnticlusterEngine(spec)
    r1, st = eng.partition(x)
    np.testing.assert_array_equal(np.asarray(r1.labels),
                                  np.asarray(res.labels))
    r2, _ = eng.repartition(x, st)
    assert r2.balanced


def test_mesh_valid_mask_flat_plan():
    x = jnp.asarray(_data(128, 4, 53))
    vm = np.ones(128, bool)
    vm[120:] = False
    spec = AnticlusterSpec(k=8, mesh=_mesh1(), data_axes=("data",),
                           valid_mask=vm)
    res = anticluster(x, spec)
    ref = anticluster(x, AnticlusterSpec(k=8, plan=None, valid_mask=vm))
    np.testing.assert_array_equal(
        np.where(vm, np.asarray(res.labels), 0),
        np.where(vm, np.asarray(ref.labels), 0))
    assert res.n_valid == 120
    eng = AnticlusterEngine(spec)
    r1, st = eng.partition(x)
    np.testing.assert_array_equal(np.asarray(r1.labels)[vm],
                                  np.asarray(res.labels)[vm])
    np.testing.assert_array_equal(np.asarray(st.moment_count), [120.0])


def test_mesh_stream_chunk_ge_n_bit_parity():
    x = jnp.asarray(_data(160, 5, 54))
    dense = AnticlusterSpec(k=8, mesh=_mesh1(), data_axes=("data",))
    stream = dense.replace(chunk_size=200)
    np.testing.assert_array_equal(
        np.asarray(anticluster(x, stream).labels),
        np.asarray(anticluster(x, dense).labels))
    eng = AnticlusterEngine(stream)
    res, st = eng.partition(x)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(anticluster(x, dense).labels))
    res2, _ = eng.repartition(x, st)
    assert res2.balanced and eng.compile_count == 1


# ---------------------------------------------------------------------------
# Validation: strict data_axes, divisibility, state types
# ---------------------------------------------------------------------------

def test_sharded_core_chunked_with_categories_stays_stratified():
    """Direct sharded_core calls (the raw jit-able entry point) must not
    let chunk_size silently bypass categories/valid_mask: the shard falls
    back to the dense masked core, same rule as hierarchical_core."""
    from repro.core.sharded import sharded_core
    rng = np.random.default_rng(67)
    x = jnp.asarray(_data(96, 3, 67))
    cats = jnp.asarray(rng.integers(0, 2, size=96).astype(np.int32))
    mesh = _mesh1()
    lab_c = sharded_core(x, 4, mesh, data_axes=("data",), categories=cats,
                         n_categories=2, chunk_size=32)
    lab_d = sharded_core(x, 4, mesh, data_axes=("data",), categories=cats,
                         n_categories=2)
    np.testing.assert_array_equal(np.asarray(lab_c), np.asarray(lab_d))
    vm = jnp.asarray(np.arange(96) < 90)
    lab_vc = sharded_core(x, 4, mesh, data_axes=("data",), valid_mask=vm,
                          chunk_size=32)
    lab_vd = sharded_core(x, 4, mesh, data_axes=("data",), valid_mask=vm)
    np.testing.assert_array_equal(np.asarray(lab_vc)[np.asarray(vm)],
                                  np.asarray(lab_vd)[np.asarray(vm)])


def test_data_axes_absent_axis_raises_with_names():
    x = jnp.asarray(_data(64, 3, 55))
    spec = AnticlusterSpec(k=4, mesh=_mesh1(), data_axes=("dta", "data"))
    with pytest.raises(ValueError, match=r"dta"):
        anticluster(x, spec)
    with pytest.raises(ValueError, match=r"dta"):
        AnticlusterEngine(spec)
    from repro.core.sharded import sharded_core
    with pytest.raises(ValueError, match=r"not present on the mesh"):
        sharded_core(x, 4, _mesh1(), data_axes=("dta",))


def test_data_axes_auto_needs_a_data_axis():
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("model",))
    with pytest.raises(ValueError, match="none of the default data axes"):
        anticluster(jnp.asarray(_data(64, 3, 56)),
                    AnticlusterSpec(k=4, mesh=mesh))


def test_mesh_rejects_indivisible_rows_and_mismatched_state():
    spec = AnticlusterSpec(k=4, mesh=_mesh1(), data_axes=("data",))
    eng = AnticlusterEngine(spec)
    x = jnp.asarray(_data(64, 3, 57))
    _, state = eng.partition(x)
    # a single-device ABAState cannot feed a mesh engine
    flat_eng = AnticlusterEngine(AnticlusterSpec(k=4, plan=None))
    _, flat_state = flat_eng.partition(x)
    with pytest.raises(TypeError, match="ShardedABAState"):
        eng.repartition(x, flat_state)
    with pytest.raises(TypeError, match="ABAState"):
        flat_eng.repartition(x, state)


# ---------------------------------------------------------------------------
# ShardedABAState pytree + checkpoint round-trips
# ---------------------------------------------------------------------------

def test_sharded_state_is_a_registered_pytree():
    spec = AnticlusterSpec(k=8, mesh=_mesh1(), data_axes=("data",))
    eng = AnticlusterEngine(spec)
    x = jnp.asarray(_data(96, 4, 58))
    _, state = eng.partition(x)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert all(isinstance(l, jax.Array) for l in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, ShardedABAState)
    jitted = jax.jit(lambda s: s)(state)
    np.testing.assert_array_equal(np.asarray(jitted.prev_labels),
                                  np.asarray(state.prev_labels))
    back = pickle.loads(pickle.dumps(jax.device_get(state)))
    res, _ = eng.repartition(x, jax.device_put(
        back, eng.state_shardings(x)))
    assert res.balanced


def test_engine_state_checkpoint_roundtrip(tmp_path):
    from repro.train.checkpoint import restore_engine_state, save_engine_state
    x = jnp.asarray(_data(120, 4, 59))
    # single-device session (ABAState)
    eng = AnticlusterEngine(AnticlusterSpec(k=6, plan=(2, 3)))
    _, state = eng.partition(x)
    save_engine_state(str(tmp_path / "flat"), 7, state)
    restored, step = restore_engine_state(str(tmp_path / "flat"), eng, x)
    assert step == 7 and isinstance(restored, ABAState)
    r_mem, _ = eng.repartition(x, state)
    r_ckpt, _ = eng.repartition(x, restored)
    np.testing.assert_array_equal(np.asarray(r_mem.labels),
                                  np.asarray(r_ckpt.labels))
    # sharded session (ShardedABAState placed back onto the mesh)
    meng = AnticlusterEngine(
        AnticlusterSpec(k=6, mesh=_mesh1(), data_axes=("data",)))
    _, mstate = meng.partition(x)
    save_engine_state(str(tmp_path / "mesh"), 3, mstate)
    mrestored, step = restore_engine_state(str(tmp_path / "mesh"), meng, x)
    assert step == 3 and isinstance(mrestored, ShardedABAState)
    for a, b in zip(jax.tree_util.tree_leaves(mstate),
                    jax.tree_util.tree_leaves(mrestored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m_mem, _ = meng.repartition(x, mstate)
    m_ckpt, _ = meng.repartition(x, mrestored)
    np.testing.assert_array_equal(np.asarray(m_mem.labels),
                                  np.asarray(m_ckpt.labels))


def test_restore_engine_state_empty_dir(tmp_path):
    from repro.train.checkpoint import restore_engine_state
    eng = AnticlusterEngine(AnticlusterSpec(k=4, plan=None))
    state, step = restore_engine_state(str(tmp_path / "nope"), eng, (64, 3))
    assert state is None and step == -1


# ---------------------------------------------------------------------------
# Consumers: sharded warm lanes
# ---------------------------------------------------------------------------

def test_service_sharded_warm_lane():
    from repro.serve import AnticlusterService
    rng = np.random.default_rng(60)
    spec = AnticlusterSpec(k=4, mesh=_mesh1(), data_axes=("data",))
    svc = AnticlusterService(spec)
    reqs = [rng.normal(size=(64, 3)).astype(np.float32) for _ in range(3)]
    outs = svc.partition_many(reqs)
    # first request is the lane's cold solve: one-shot parity bit for bit;
    # later same-shape requests warm-start from the carried shard prices
    one = anticluster(jnp.asarray(reqs[0]), spec)
    np.testing.assert_array_equal(np.asarray(outs[0].labels),
                                  np.asarray(one.labels))
    for r, xi in zip(outs, reqs):
        assert r.balanced
        xj = jnp.asarray(xi)
        o_warm = float(objective_centroid(xj, r.labels, 4))
        o_ref = float(objective_centroid(
            xj, anticluster(xj, spec).labels, 4))
        assert abs(o_warm - o_ref) / abs(o_ref) < 0.01
    # mesh lanes never stack: one solo lane per signature, warm after that
    assert svc.lane_count == 1
    assert isinstance(svc._lanes[("solo", (64, 3))].state, ShardedABAState)
    outs2 = svc.partition_many(reqs)
    assert svc.lane_count == 1 and all(r.balanced for r in outs2)


def test_sequencer_mesh_epochs_compile_once():
    from repro.data.minibatch import ABABatchSequencer
    rng = np.random.default_rng(61)
    feats = rng.normal(size=(256, 5)).astype(np.float32)
    seq = ABABatchSequencer(feats, 32, chunk_size=None, mesh=_mesh1(),
                            data_axes=("data",))
    assert seq.engine.spec.mesh is not None
    assert seq.engine.compile_count == 1
    for epoch in range(1, 3):
        feats = feats + rng.normal(size=feats.shape).astype(np.float32) * .05
        batches = list(seq.epoch(epoch, features=feats))
        flat = np.sort(np.concatenate(batches))
        np.testing.assert_array_equal(flat, np.arange(256))
    assert seq.engine.compile_count == 1


def test_folds_mesh_engine():
    from repro.data.folds import aba_folds, fold_engine
    feats = _data(128, 4, 62)
    eng = fold_engine(4, mesh=_mesh1(), data_axes=("data",))
    labels = aba_folds(feats, 4, engine=eng)
    assert balance_ok(labels, 4, 128)
    assert eng.compile_count == 1


def test_sequencer_mesh_unplaceable_k_falls_back():
    from repro.data.minibatch import ABABatchSequencer
    feats = _data(56, 4, 63)
    with pytest.warns(RuntimeWarning, match="single-device"):
        seq = ABABatchSequencer(feats, 8, max_k=4, mesh=_mesh1(),
                                data_axes=("data",))  # k=7 prime > max_k
    assert seq.engine.spec.mesh is None


# ---------------------------------------------------------------------------
# Two-device semantics (CI mesh smoke job; skipped under tier-1's 1 device)
# ---------------------------------------------------------------------------

@_multi
def test_two_device_engine_matches_oneshot_and_never_retraces():
    rng = np.random.default_rng(64)
    x = jnp.asarray(_data(256, 6, 64))
    spec = AnticlusterSpec(k=16, mesh=_mesh2(), data_axes=("data",))
    one = anticluster(x, spec)
    assert one.plan[0] == 2  # the sharding is the first hierarchy level
    eng = AnticlusterEngine(spec)
    res, state = eng.partition(x)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(one.labels))
    # zeroed ShardedABAState reproduces the cold result bit for bit
    res0, _ = eng.repartition(x, eng.init_state(x))
    np.testing.assert_array_equal(np.asarray(res0.labels),
                                  np.asarray(one.labels))
    # state leaves live sharded across the mesh
    assert state.prices[0].shape[0] == 2
    assert len(state.prices[0].sharding.device_set) == 2
    xs = np.asarray(x)
    for _ in range(3):
        xs = xs + rng.normal(size=xs.shape).astype(np.float32) * 0.05
        res, state = eng.repartition(jnp.asarray(xs), state)
        assert res.balanced
    assert eng.compile_count == 1  # zero retraces after the first call
    # per-shard locality: shard s owns labels [s*8, (s+1)*8)
    lab = np.asarray(res.labels)
    for s in range(2):
        seg = lab[s * 128:(s + 1) * 128]
        assert seg.min() >= s * 8 and seg.max() < (s + 1) * 8


@_multi
def test_two_device_stream_and_categories():
    rng = np.random.default_rng(65)
    x = jnp.asarray(_data(256, 5, 65))
    dense = AnticlusterSpec(k=8, mesh=_mesh2(), data_axes=("data",))
    stream = dense.replace(chunk_size=512)  # >= per-shard rows: bit parity
    np.testing.assert_array_equal(
        np.asarray(anticluster(x, stream).labels),
        np.asarray(anticluster(x, dense).labels))
    cats = rng.integers(0, 4, size=256).astype(np.int32)
    res = anticluster(x, dense.replace(categories=cats))
    assert res.balanced
    # per-shard stratification: within each shard every anticluster's
    # category count obeys constraint (5) for that shard's rows
    lab = np.asarray(res.labels)
    for s in range(2):
        rows = slice(s * 128, (s + 1) * 128)
        local_cats, local_lab = cats[rows], lab[rows]
        for g in range(4):
            n_g = int((local_cats == g).sum())
            per = np.bincount(local_lab[local_cats == g] - s * 4,
                              minlength=4)
            assert per.max() <= -(-n_g // 4) and per.min() >= n_g // 4


@_multi
def test_two_device_uneven_rows_autopad_parity():
    # 65 rows on 2 shards: the engine pads one masked zero row instead of
    # raising, riding the per-call valid_mask executable
    x = jnp.asarray(_data(65, 3, 70))
    spec = AnticlusterSpec(k=4, mesh=_mesh2(), data_axes=("data",))
    res = anticluster(x, spec)
    assert res.labels.shape == (65,)
    counts = np.bincount(np.asarray(res.labels), minlength=4)
    assert counts.min() >= 65 // 4 and counts.max() <= -(-65 // 4)
    # parity: identical to padding by hand and masking the pad row
    pad = jnp.concatenate([x, jnp.zeros((1, 3), x.dtype)])
    ref = anticluster(pad, spec.replace(valid_mask=np.arange(66) < 65))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(ref.labels)[:65])
    # the engine agrees, and warm repartitions stay on one executable
    eng = AnticlusterEngine(spec)
    r1, st = eng.partition(x)
    np.testing.assert_array_equal(np.asarray(r1.labels),
                                  np.asarray(res.labels))
    r2, _ = eng.repartition(x, st)
    assert r2.balanced and eng.compile_count == 1
    # a user-provided mask on uneven rows still raises the explicit error
    with pytest.raises(ValueError, match="divisible"):
        anticluster(x, spec.replace(valid_mask=np.ones(65, bool)))


@_multi
def test_two_device_presharded_input_and_checkpoint(tmp_path):
    from repro.train.checkpoint import restore_engine_state, save_engine_state
    mesh = _mesh2()
    x = jnp.asarray(_data(192, 4, 66))
    spec = AnticlusterSpec(k=8, mesh=mesh, data_axes=("data",))
    eng = AnticlusterEngine(spec)
    xsh = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    res, state = eng.partition(xsh)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(anticluster(x, spec).labels))
    save_engine_state(str(tmp_path / "m2"), 1, state)
    restored, _ = restore_engine_state(str(tmp_path / "m2"), eng, x)
    assert len(restored.prices[0].sharding.device_set) == 2
    r_mem, _ = eng.repartition(xsh, state)
    r_ckpt, _ = eng.repartition(xsh, restored)
    np.testing.assert_array_equal(np.asarray(r_mem.labels),
                                  np.asarray(r_ckpt.labels))
