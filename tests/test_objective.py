"""Fact 1 and the objective machinery."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import (centroids, cluster_sizes, diversity_per_cluster,
                        objective_centroid, objective_pairwise,
                        total_pairwise)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 60), d=st.integers(1, 8), k=st.integers(1, 6),
       seed=st.integers(0, 1000))
def test_fact1_identity(n, d, k, seed):
    """sum_{i<i' in C_k} ||xi - xi'||^2 == n_k * sum_i ||xi - mu_k||^2."""
    rng = np.random.default_rng(seed)
    k = min(k, n)
    x = rng.normal(size=(n, d))
    labels = rng.integers(0, k, size=n)
    brute = 0.0
    for i in range(n):
        for j in range(i + 1, n):
            if labels[i] == labels[j]:
                brute += ((x[i] - x[j]) ** 2).sum()
    w = float(objective_pairwise(jnp.asarray(x.astype(np.float32)),
                                 jnp.asarray(labels.astype(np.int32)), k))
    assert abs(w - brute) <= 1e-3 * max(1.0, abs(brute))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 40), seed=st.integers(0, 100))
def test_total_pairwise(n, seed):
    x = np.random.default_rng(seed).normal(size=(n, 3))
    brute = sum(((x[i] - x[j]) ** 2).sum()
                for i in range(n) for j in range(i + 1, n))
    t = float(total_pairwise(jnp.asarray(x.astype(np.float32))))
    assert abs(t - brute) <= 1e-3 * max(1.0, brute)


def test_centroids_and_sizes(rng):
    x = rng.normal(size=(30, 4)).astype(np.float32)
    labels = rng.integers(0, 3, size=30).astype(np.int32)
    c = np.asarray(centroids(jnp.asarray(x), jnp.asarray(labels), 3))
    s = np.asarray(cluster_sizes(jnp.asarray(labels), 3))
    for g in range(3):
        np.testing.assert_allclose(c[g], x[labels == g].mean(0), rtol=1e-5)
        assert s[g] == (labels == g).sum()
    div = np.asarray(diversity_per_cluster(jnp.asarray(x),
                                           jnp.asarray(labels), 3))
    o = float(objective_centroid(jnp.asarray(x), jnp.asarray(labels), 3))
    np.testing.assert_allclose(div.sum(), o, rtol=1e-5)
