"""Data pipeline: ABA mini-batch sequencer, CV folds, synthetic generators."""

import numpy as np
import jax.numpy as jnp

from repro.core.objective import diversity_per_cluster
from repro.data.folds import aba_folds, fold_splits
from repro.data.minibatch import ABABatchSequencer, random_sequencer_batches
from repro.data import synthetic


def test_sequencer_partition_and_determinism():
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(512, 8)).astype(np.float32)
    s1 = ABABatchSequencer(feats, 32, seed=1)
    s2 = ABABatchSequencer(feats, 32, seed=1)
    assert len(s1) == 16
    np.testing.assert_array_equal(s1.batches, s2.batches)  # deterministic
    flat = np.sort(s1.batches.reshape(-1))
    np.testing.assert_array_equal(flat, np.arange(512))  # exact partition
    # epoch order deterministic given epoch index
    e0a = [b.tolist() for b in s1.epoch(0)]
    e0b = [b.tolist() for b in s2.epoch(0)]
    assert e0a == e0b
    assert e0a != [b.tolist() for b in s1.epoch(1)]


def test_sequencer_more_balanced_than_random():
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(600, 6)).astype(np.float32)
    seq = ABABatchSequencer(feats, 50, seed=0)
    sd_aba, _ = seq.diversity_stats()
    rb = random_sequencer_batches(600, 50, seed=0)
    lab = np.zeros(600, np.int32)
    for b, idx in enumerate(rb):
        lab[idx] = b
    div = np.asarray(diversity_per_cluster(jnp.asarray(feats),
                                           jnp.asarray(lab), 12))
    assert sd_aba < float(div.std())


def test_folds_stratified():
    rng = np.random.default_rng(2)
    feats = rng.normal(size=(300, 5)).astype(np.float32)
    cats = rng.integers(0, 3, size=300).astype(np.int32)
    labels = aba_folds(feats, 5, categories=cats)
    for g in range(3):
        counts = np.bincount(labels[cats == g], minlength=5)
        ng = (cats == g).sum()
        assert counts.min() >= ng // 5 and counts.max() <= -(-ng // 5)
    splits = list(fold_splits(labels, 5))
    assert len(splits) == 5
    for tr, va in splits:
        assert len(tr) + len(va) == 300
        assert not set(tr) & set(va)


def test_synthetic_presets():
    x = synthetic.load("abalone", max_n=1000)
    assert x.shape == (1000, 10)
    assert np.isfinite(x).all()
    tok, feats = synthetic.lm_token_stream(64, 32, 1000)
    assert tok.shape == (64, 32) and tok.max() < 1000 and tok.min() >= 0
    assert feats.shape[0] == 64
