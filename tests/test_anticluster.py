"""The spec-driven front door: dispatch parity with the legacy entry points
(bit-for-bit), the solver registry, the default_plan max_k contract, the
result object, and the public-API snapshot."""

import math
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.anticluster
import repro.core
from repro.anticluster import (AnticlusterSpec, AnticlusterResult,
                               anticluster, available_solvers, get_solver,
                               register_solver)
from repro.core import (aba, aba_auto, aba_batched, default_plan,
                        hierarchical_aba)
from repro.core.assignment import scipy_solve_jax
from repro.core.objective import balance_ok, objective_centroid
from repro.core.sharded import sharded_aba


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _legacy(fn, *args, **kw):
    """Call a deprecated entry point, asserting it warns."""
    with pytest.warns(DeprecationWarning):
        return np.asarray(fn(*args, **kw))


# ---------------------------------------------------------------------------
# Shim parity: every legacy entry point == the equivalent anticluster() call
# ---------------------------------------------------------------------------

def test_flat_auction_parity():
    x = jnp.asarray(_data(300, 6))
    res = anticluster(x, k=7, plan=None)
    np.testing.assert_array_equal(_legacy(aba, x, 7), np.asarray(res.labels))
    assert res.plan == (7,) and res.balanced


def test_categorical_parity():
    rng = np.random.default_rng(5)
    x = jnp.asarray(_data(500, 5, 5))
    cats = rng.integers(0, 4, size=500).astype(np.int32)
    legacy = _legacy(aba, x, 6, categories=jnp.asarray(cats), n_categories=4)
    res = anticluster(x, k=6, plan=None, categories=cats)
    np.testing.assert_array_equal(legacy, np.asarray(res.labels))


def test_hierarchical_auto_plan_parity():
    x = jnp.asarray(_data(2000, 6, 1))
    legacy = _legacy(aba_auto, x, 100, max_k=30)
    res = anticluster(x, k=100, max_k=30)
    assert len(res.plan) > 1  # a k=5000-style multi-level route, scaled down
    np.testing.assert_array_equal(legacy, np.asarray(res.labels))
    assert res.balanced


def test_explicit_plan_parity():
    x = jnp.asarray(_data(600, 6, 2))
    legacy = _legacy(hierarchical_aba, x, (4, 6))
    res = anticluster(x, k=24, plan=(4, 6))
    np.testing.assert_array_equal(legacy, np.asarray(res.labels))


def test_fused_solver_parity():
    x = jnp.asarray(_data(300, 5, 3))
    legacy = _legacy(aba, x, 6, solver="auction_fused")
    res = anticluster(x, k=6, plan=None, solver="auction_fused")
    np.testing.assert_array_equal(legacy, np.asarray(res.labels))


def test_stacked_rank_parity():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(4, 40, 5)).astype(np.float32)
    vm = np.zeros((4, 40), bool)
    for g, v in enumerate([40, 39, 40, 37]):
        vm[g, :v] = True
    legacy = _legacy(aba_batched, jnp.asarray(x), 5, jnp.asarray(vm))
    res = anticluster(x, k=5, plan=None, variant="base", valid_mask=vm)
    np.testing.assert_array_equal(np.where(vm, legacy, 0),
                                  np.where(vm, np.asarray(res.labels), 0))
    assert res.cluster_sizes.shape == (4, 5)
    np.testing.assert_array_equal(res.n_valid, [40, 39, 40, 37])
    assert res.balanced


def test_sharded_parity(one_device_mesh):
    x = jnp.asarray(_data(128, 4, 6))
    legacy = _legacy(sharded_aba, x, 8, one_device_mesh,
                     data_axes=("data",))
    res = anticluster(x, k=8, mesh=one_device_mesh, data_axes=("data",))
    np.testing.assert_array_equal(legacy, np.asarray(res.labels))


def test_every_legacy_entry_point_warns():
    x = jnp.asarray(_data(60, 3, 7))
    _legacy(aba, x, 4)
    _legacy(aba_batched, x[None], 4, jnp.ones((1, 60), bool))
    _legacy(hierarchical_aba, x, (2, 2))
    _legacy(aba_auto, x, 4)


# ---------------------------------------------------------------------------
# Solver registry
# ---------------------------------------------------------------------------

def test_registry_roundtrip_custom_solver():
    name = "test_hungarian"
    if name not in available_solvers():
        register_solver(name, scipy_solve_jax)
    assert name in available_solvers()
    assert get_solver(name).solve is scipy_solve_jax
    x = jnp.asarray(_data(200, 5, 8))
    res = anticluster(x, k=5, plan=None, solver=name)
    assert balance_ok(np.asarray(res.labels), 5)
    # the exact-LAP backend tracks the numpy Algorithm-1 reference (float32
    # vs float64 centroid accumulation is the only difference left)
    from repro.core import aba_reference
    ref = aba_reference(_data(200, 5, 8), 5)
    o_res = float(objective_centroid(x, res.labels, 5))
    o_ref = float(objective_centroid(x, jnp.asarray(ref), 5))
    assert abs(o_res - o_ref) / abs(o_ref) < 2e-3


def test_registry_duplicate_and_unknown():
    with pytest.raises(ValueError, match="already registered"):
        register_solver("auction", scipy_solve_jax)
    with pytest.raises(KeyError, match="unknown solver"):
        get_solver("no_such_solver")
    with pytest.raises(KeyError, match="no_such_solver"):
        anticluster(jnp.asarray(_data(40, 3)), k=4, solver="no_such_solver")


def test_registry_default_entries():
    for name in ("auction", "auction_fused", "greedy", "scipy"):
        assert name in available_solvers()
    assert get_solver("auction_fused").factored is not None


# ---------------------------------------------------------------------------
# default_plan max_k contract (regression: prime / unfactorable k)
# ---------------------------------------------------------------------------

def test_default_plan_respects_max_k():
    for k, max_k in [(5000, 512), (5000, 100), (1018, 512), (720, 16),
                     (131072, 256), (505, 101)]:
        plan = default_plan(k, max_k)
        assert math.prod(plan) == k
        assert all(f <= max_k for f in plan), (k, max_k, plan)


def test_default_plan_large_prime_factor_at_the_limit():
    # 1030 = 2 * 5 * 103: admissible only because 103 <= max_k exactly; the
    # legacy greedy returned (k,)-style contract violations in this regime
    plan = default_plan(1030, 103)
    assert math.prod(plan) == 1030 and all(f <= 103 for f in plan)
    assert 103 in plan


@pytest.mark.parametrize("k,max_k", [(521, 512), (515, 100), (1042, 512)])
def test_default_plan_raises_when_unfactorable(k, max_k):
    with pytest.raises(ValueError, match="max_k"):
        default_plan(k, max_k)


def test_spec_plan_validation():
    with pytest.raises(ValueError, match="prod"):
        AnticlusterSpec(k=10, plan=(3, 4))
    with pytest.raises(ValueError, match="plan"):
        AnticlusterSpec(k=10, plan="fastest")
    with pytest.raises(ValueError, match="k="):
        AnticlusterSpec(k=0)


# ---------------------------------------------------------------------------
# Categorical + hierarchy (the aba_folds fix) and the result object
# ---------------------------------------------------------------------------

def test_categorical_hierarchical_constraint5():
    """Stratification composes across levels: constraint (5) holds globally."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(_data(600, 5, 9))
    cats = rng.integers(0, 3, size=600).astype(np.int32)
    res = anticluster(x, k=12, plan=(3, 4), categories=cats)
    lab = np.asarray(res.labels)
    assert res.balanced and balance_ok(lab, 12, 600)
    for g in range(3):
        counts = np.bincount(lab[cats == g], minlength=12)
        ng = (cats == g).sum()
        assert counts.min() >= ng // 12 and counts.max() <= -(-ng // 12)


def test_folds_take_hierarchy_with_categories():
    """aba_folds no longer drops the hierarchy when categories are given."""
    from repro.data.folds import aba_folds
    rng = np.random.default_rng(10)
    feats = _data(400, 4, 10)
    cats = rng.integers(0, 2, size=400).astype(np.int32)
    labels = aba_folds(feats, 8, categories=cats, max_k=4)  # forces (k1, k2)
    assert balance_ok(labels, 8, 400)
    for g in range(2):
        counts = np.bincount(labels[cats == g], minlength=8)
        ng = (cats == g).sum()
        assert counts.min() >= ng // 8 and counts.max() <= -(-ng // 8)


@pytest.mark.parametrize("n,k", [(103, 5), (101, 4), (37, 7)])
def test_result_sizes_when_k_does_not_divide_n(n, k):
    """Proposition 1 through the result object: sizes differ by at most 1."""
    res = anticluster(jnp.asarray(_data(n, 4, n)), k=k, plan=None)
    sizes = np.asarray(res.cluster_sizes)
    assert sizes.sum() == n and res.n_valid == n
    assert sizes.min() == n // k and sizes.max() == -(-n // k)
    assert res.balanced
    assert balance_ok(np.asarray(res.labels), k, n)


def test_result_is_a_pytree():
    res = anticluster(jnp.asarray(_data(60, 3, 11)), k=4, plan=None)
    leaves, treedef = jax.tree_util.tree_flatten(res)
    res2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(res2, AnticlusterResult)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(res2.labels))
    assert res2.plan == res.plan and res2.solver == res.solver


def test_spec_overrides_and_replace():
    x = jnp.asarray(_data(80, 3, 12))
    spec = AnticlusterSpec(k=4, plan=None)
    r1 = anticluster(x, spec)
    r2 = anticluster(x, spec, solver="scipy")
    assert r1.solver == "auction" and r2.solver == "scipy"
    assert spec.replace(solver="greedy").solver == "greedy"
    assert spec.solver == "auction"  # frozen: replace does not mutate


def test_stats_false_skips_diversity_only():
    x = jnp.asarray(_data(90, 3, 14))
    full = anticluster(x, k=4, plan=None)
    lean = anticluster(x, k=4, plan=None, stats=False)
    np.testing.assert_array_equal(np.asarray(full.labels),
                                  np.asarray(lean.labels))
    np.testing.assert_array_equal(np.asarray(full.cluster_sizes),
                                  np.asarray(lean.cluster_sizes))
    assert float(lean.diversity_sd) == 0.0 and lean.balanced


def test_result_stats_match_objective_helpers():
    """Drift guard: the masked stats equal the flat objective helpers."""
    from repro.anticluster import _result_stats
    from repro.core.objective import cluster_sizes, diversity_stats
    x = jnp.asarray(_data(150, 4, 15))
    res = anticluster(x, k=6, plan=None)
    np.testing.assert_array_equal(
        np.asarray(res.cluster_sizes), np.asarray(cluster_sizes(res.labels, 6)))
    sd, rng = diversity_stats(x, res.labels, 6)
    np.testing.assert_allclose(float(res.diversity_sd), float(sd), rtol=1e-5)
    np.testing.assert_allclose(float(res.diversity_range), float(rng),
                               rtol=1e-5)


def test_data_layer_falls_back_flat_on_unfactorable_k():
    """k derived from data size must not crash when it has no plan."""
    from repro.data.minibatch import ABABatchSequencer
    from repro.data.folds import aba_folds
    feats = _data(56, 4, 16)
    with pytest.warns(RuntimeWarning, match="flat single-level"):
        seq = ABABatchSequencer(feats, 8, max_k=4)  # k = 7, prime > max_k
    assert len(seq) == 7 and seq.result.plan == (7,)
    with pytest.warns(RuntimeWarning, match="flat single-level"):
        labels = aba_folds(feats, 7, max_k=4)
    assert balance_ok(labels, 7, 56)


def test_kplus_rejects_stacked_or_masked_input():
    x3 = _data(60, 4, 17).reshape(3, 20, 4)
    with pytest.raises(NotImplementedError, match="kplus"):
        anticluster(x3, k=4, plan=None, kplus_moments=2)
    x2 = _data(40, 4, 18)
    with pytest.raises(NotImplementedError, match="kplus"):
        anticluster(x2, k=4, plan=None, kplus_moments=2,
                    valid_mask=np.arange(40) < 30)


def test_kplus_spec_field():
    x = _data(240, 3, 13)
    res = anticluster(x, k=4, plan=None, kplus_moments=2)
    assert res.balanced
    from repro.core.kplus import moment_spread
    lab = np.asarray(res.labels)
    plain = np.asarray(anticluster(x, k=4, plan=None).labels)
    assert (moment_spread(x, lab, 4, moment=2)
            <= moment_spread(x, plain, 4, moment=2) * 1.5)


# ---------------------------------------------------------------------------
# Streaming execution path (chunk_size)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["auction", "auction_fused"])
def test_stream_parity_with_flat(solver):
    """The acceptance contract: chunk_size >= n is bit-for-bit label-equal
    to the flat dense path, for the dense and the matrix-free solver."""
    x = jnp.asarray(_data(300, 6, 21))
    flat = np.asarray(anticluster(x, k=7, plan=None, solver=solver).labels)
    for cs in (300, 301, 1200):
        res = anticluster(x, k=7, plan=None, solver=solver, chunk_size=cs)
        np.testing.assert_array_equal(flat, np.asarray(res.labels))


def test_stream_parity_interleave_variant():
    x = jnp.asarray(_data(256, 4, 22))
    flat = np.asarray(anticluster(x, k=64, plan=None,
                                  variant="interleave").labels)
    res = anticluster(x, k=64, plan=None, variant="interleave",
                      chunk_size=256)
    np.testing.assert_array_equal(flat, np.asarray(res.labels))


@pytest.mark.parametrize("n,k,cs", [(300, 7, 49), (257, 16, 16), (300, 6, 100)])
def test_stream_multichunk_balance_and_quality(n, k, cs):
    """Chunks smaller than n keep Proposition 1 and the objective: only the
    centroid accumulation order changes, never the assignment structure."""
    x = jnp.asarray(_data(n, 5, n))
    flat = anticluster(x, k=k, plan=None)
    res = anticluster(x, k=k, plan=None, chunk_size=cs)
    assert res.balanced and balance_ok(np.asarray(res.labels), k, n)
    of = float(objective_centroid(x, flat.labels, k))
    os = float(objective_centroid(x, res.labels, k))
    assert abs(os - of) / abs(of) < 5e-3


def test_stream_hierarchical_level1_parity():
    """chunk_size streams level 1 of a hierarchy; one covering chunk is
    bit-identical to the dense hierarchical route."""
    x = jnp.asarray(_data(600, 6, 23))
    dense = anticluster(x, k=24, plan=(4, 6))
    res = anticluster(x, k=24, plan=(4, 6), chunk_size=600)
    np.testing.assert_array_equal(np.asarray(dense.labels),
                                  np.asarray(res.labels))


def test_stream_auto_small_n_stays_dense():
    x = jnp.asarray(_data(200, 4, 24))
    dense = anticluster(x, k=5, plan=None)
    auto = anticluster(x, k=5, plan=None, chunk_size="auto")
    np.testing.assert_array_equal(np.asarray(dense.labels),
                                  np.asarray(auto.labels))
    assert auto.solver == "auction"  # no at-scale solver upgrade either


def test_stream_auto_at_scale_upgrades_to_factored(monkeypatch):
    """At scale, auto-streaming makes the matrix-free factored auction the
    default engine (threshold monkeypatched so the test stays tiny)."""
    monkeypatch.setattr(repro.anticluster, "_AUTO_STREAM_MIN", 128)
    monkeypatch.setattr(repro.anticluster, "_AUTO_CHUNK_ROWS", 64)
    x = jnp.asarray(_data(200, 4, 25))
    res = anticluster(x, k=5, plan=None, chunk_size="auto")
    assert res.solver == "auction_fused"
    assert res.balanced and balance_ok(np.asarray(res.labels), 5, 200)
    # an explicitly chosen solver is never silently swapped
    res2 = anticluster(x, k=5, plan=None, chunk_size="auto", solver="greedy")
    assert res2.solver == "greedy"


def test_stream_explicit_chunk_rejects_unstreamable_input():
    # categories and valid_mask stream since the chunked rank-in-category
    # rearrangement landed; only stacked (G, M, D) input stays dense
    x3 = jnp.asarray(_data(120, 4, 26)).reshape(2, 60, 4)
    with pytest.raises(NotImplementedError, match="chunk_size"):
        anticluster(x3, k=4, plan=None, chunk_size=64)
    # ...while flat categorical/masked input now streams instead of raising
    x = jnp.asarray(_data(120, 4, 26))
    cats = np.asarray(
        np.random.default_rng(27).integers(0, 3, 120), np.int32)
    res = anticluster(x, k=4, plan=None, chunk_size=64, categories=cats)
    assert res.balanced
    res = anticluster(x, k=4, plan=None, chunk_size=64,
                      valid_mask=np.arange(120) < 100)
    assert int(res.n_valid) == 100


def test_stream_spec_validation():
    with pytest.raises(ValueError, match="chunk_size"):
        AnticlusterSpec(k=4, chunk_size=0)
    with pytest.raises(ValueError, match="chunk_size"):
        AnticlusterSpec(k=4, chunk_size="fastest")
    assert AnticlusterSpec(k=4, chunk_size="auto").resolve_chunk(100, 4) \
        is None  # below the auto threshold
    assert AnticlusterSpec(k=4, chunk_size=77).resolve_chunk(100, 4) == 77


def test_fused_solver_hierarchical_stack():
    """Regression: the factored path must handle G>1 stacks with dummy rows
    (hierarchical level >= 2 feeds padded group batches through it; the
    (G,) dummy-row top-2 must broadcast across the row axis)."""
    x = jnp.asarray(_data(600, 6, 28))
    res = anticluster(x, k=24, plan=(4, 6), solver="auction_fused")
    assert res.balanced and balance_ok(np.asarray(res.labels), 24, 600)
    dense = anticluster(x, k=24, plan=(4, 6))
    od = float(objective_centroid(x, dense.labels, 24))
    of = float(objective_centroid(x, res.labels, 24))
    assert abs(of - od) / abs(od) < 5e-3


# ---------------------------------------------------------------------------
# scipy host-callback solver through the front door (ROADMAP open item)
# ---------------------------------------------------------------------------

def test_scipy_solver_stats_no_deadlock():
    """The "scipy" registry solver (jax.pure_callback) must run through
    anticluster() WITH eager result statistics: the blocks-on-labels guard
    is load-bearing -- dispatching the stats ops while the callback solve is
    in flight deadlocks CPU jax (a hang here, caught by CI's job timeout,
    is that regression)."""
    x = jnp.asarray(_data(150, 4, 27))
    res = anticluster(x, k=6, plan=None, solver="scipy", stats=True)
    assert res.balanced and int(res.n_valid) == 150
    assert np.isfinite(float(res.diversity_sd))
    assert np.isfinite(float(res.diversity_range))
    # and again through a hierarchy (two sequential callback regimes)
    res_h = anticluster(x, k=6, plan=(2, 3), solver="scipy")
    assert res_h.balanced and np.isfinite(float(res_h.diversity_sd))


# ---------------------------------------------------------------------------
# Public-API snapshot
# ---------------------------------------------------------------------------

def test_public_api_snapshot():
    assert repro.anticluster.__all__ == [
        "AnticlusterSpec", "AnticlusterResult", "anticluster",
        "AnticlusterEngine", "ABAState", "ShardedABAState",
        "PendingRepartition",
        "register_solver", "get_solver", "available_solvers",
    ]
    assert repro.core.__all__ == [
        "aba", "aba_batched", "aba_core", "aba_reference", "aba_stream",
        "delta_moments", "interleave_permutation",
        "AuctionConfig", "auction_solve", "auction_solve_factored",
        "greedy_solve", "scipy_solve", "assignment_value",
        "register_solver", "get_solver", "available_solvers",
        "solve_restricted_slots",
        "aba_auto", "default_plan", "hierarchical_aba", "hierarchical_core",
        "balance_ok", "centroids",
        "cluster_sizes", "cut_cost", "diversity_per_cluster",
        "diversity_stats",
        "dual_certificate",
        "objective_centroid", "objective_pairwise", "total_pairwise",
        "baselines",
    ]
    for name in repro.core.__all__:
        assert hasattr(repro.core, name)
    for name in repro.anticluster.__all__:
        assert hasattr(repro.anticluster, name)
