"""Streaming with constraints: the lifted categories/valid_mask ban.

The contract under test, layer by layer:

* ``aba_stream`` with ``categories`` / ``fair_codes`` / ``valid_mask`` is
  **bit-for-bit identical** to the dense categorical core whenever one chunk
  covers all rows (the chunked rank-in-category rearrangement is
  integer-exact, so the permutation -- and therefore every label -- matches
  exactly, at any chunk size for the ordering and end-to-end at chunk >= n).
* Below chunk < n the labels may differ from dense (assignment sees chunk
  boundaries) but the *invariants* hold: exact cluster balance, exact
  per-stratum balance for single-attribute constraints (spread <= 1), and
  best-effort multi-attribute quotas no worse than the dense path on the
  same data.
* The same guarantees flow through every route that reaches the streaming
  core: flat front door, hierarchical level 1, and the warm engine.
* ``chunk_size="auto"`` fallbacks to the dense core are *loud*: a
  RuntimeWarning (once per route) names the reason.
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.anticluster import (AnticlusterEngine, AnticlusterSpec,
                               _route, _WARNED_FALLBACKS, anticluster)
from repro.core.aba import aba_core, aba_stream


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _cats(n, c, seed=1):
    return np.random.default_rng(seed).integers(0, c, size=n).astype(np.int32)


def _stratum_spread(labels, cats, k):
    """Max over category values of (max - min) per-cluster count."""
    worst = 0
    for v in np.unique(cats):
        cnt = np.bincount(labels[cats == v], minlength=k)
        worst = max(worst, int(cnt.max() - cnt.min()))
    return worst


# ---------------------------------------------------------------------------
# chunk >= n: bit-for-bit parity with the dense categorical core
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [364, 400, 4096])
def test_stream_categories_parity_chunk_ge_n(chunk):
    x = jnp.asarray(_data(364, 5))
    cats = jnp.asarray(_cats(364, 4))
    dense = np.asarray(aba_core(x[None], 7, categories=cats[None],
                                n_categories=4)[0])
    stream = np.asarray(aba_stream(x, 7, chunk, categories=cats,
                                   n_categories=4))
    np.testing.assert_array_equal(stream, dense)


def test_stream_categories_mask_parity_chunk_ge_n():
    n, k = 300, 6
    x = jnp.asarray(_data(n, 4, 2))
    cats = jnp.asarray(_cats(n, 3, 3))
    vm = jnp.asarray(np.arange(n) < 260)
    dense = np.asarray(aba_core(x[None], k, vm[None], categories=cats[None],
                                n_categories=3)[0])
    stream = np.asarray(aba_stream(x, k, n, categories=cats, n_categories=3,
                                   valid_mask=vm))
    vmn = np.asarray(vm)
    # labels on padding rows are unspecified; compare where the mask is real
    np.testing.assert_array_equal(stream[vmn], dense[vmn])


def test_stream_mask_only_parity_chunk_ge_n():
    n, k = 250, 5
    x = jnp.asarray(_data(n, 6, 4))
    vm = jnp.asarray(np.arange(n) < 233)
    dense = np.asarray(aba_core(x[None], k, vm[None])[0])
    stream = np.asarray(aba_stream(x, k, n, valid_mask=vm))
    vmn = np.asarray(vm)
    np.testing.assert_array_equal(stream[vmn], dense[vmn])


def test_fairness_single_attr_is_exactly_categories():
    # fairness= with ONE attribute must resolve to the identical constraint
    # (and therefore identical labels) as categories=
    x = _data(420, 5, 7)
    cats = _cats(420, 5, 8)
    a = anticluster(x, k=6, plan=None, categories=cats)
    b = anticluster(x, k=6, plan=None, fairness=[cats])
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))
    c = anticluster(x, k=6, plan=None, fairness=[cats], chunk_size=420)
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(c.labels))


def test_fairness_multi_attr_stream_parity_chunk_ge_n():
    x = _data(360, 4, 9)
    a1 = _cats(360, 3, 10)
    a2 = _cats(360, 2, 11)
    dense = anticluster(x, k=6, plan=None, fairness={"site": a1, "grp": a2})
    stream = anticluster(x, k=6, plan=None, fairness={"site": a1, "grp": a2},
                         chunk_size=512)
    np.testing.assert_array_equal(np.asarray(dense.labels),
                                  np.asarray(stream.labels))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(40, 300), k=st.integers(2, 8), c=st.integers(2, 5),
       seed=st.integers(0, 50))
def test_stream_categories_parity_property(n, k, c, seed):
    if k > n:
        k = 2
    x = jnp.asarray(_data(n, 3, seed))
    cats = jnp.asarray(_cats(n, c, seed + 1))
    dense = np.asarray(aba_core(x[None], k, categories=cats[None],
                                n_categories=c)[0])
    stream = np.asarray(aba_stream(x, k, n, categories=cats, n_categories=c))
    np.testing.assert_array_equal(stream, dense)


# ---------------------------------------------------------------------------
# chunk < n: invariants (balance, stratification, best-effort multi-attr)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,cs,c", [(400, 8, 96, 4), (600, 6, 128, 3),
                                      (512, 16, 130, 5)])
def test_stream_categories_multichunk_invariants(n, k, cs, c):
    x = _data(n, 5, 20)
    cats = _cats(n, c, 21)
    res = anticluster(x, k=k, plan=None, categories=cats, chunk_size=cs,
                      solver="auction")
    lab = np.asarray(res.labels)
    cnt = np.bincount(lab, minlength=k)
    assert cnt.min() >= n // k and cnt.max() <= -(-n // k)
    # single-attribute stratification is exact at ANY chunk size
    assert _stratum_spread(lab, cats, k) <= 1


@settings(max_examples=10, deadline=None)
@given(n=st.integers(100, 400), k=st.integers(2, 8), c=st.integers(2, 4),
       cs=st.integers(40, 200), seed=st.integers(0, 50))
def test_stream_categories_multichunk_property(n, k, c, cs, seed):
    x = _data(n, 3, seed)
    cats = _cats(n, c, seed + 7)
    res = anticluster(x, k=k, plan=None, categories=cats, chunk_size=cs,
                      solver="auction")
    lab = np.asarray(res.labels)
    cnt = np.bincount(lab, minlength=k)
    assert cnt.min() >= n // k and cnt.max() <= -(-n // k)
    assert _stratum_spread(lab, cats, k) <= 1


@pytest.mark.parametrize("seed", [1, 2])
def test_fairness_multi_attr_stream_no_worse_than_dense(seed):
    # multi-attribute quotas are best-effort (an infeasible transversal
    # overflows by the conflicting rows -- on dense and stream alike); the
    # pinned contract is that streaming is no LOOSER than dense on the same
    # data, and cluster balance stays exact
    n, k = 360, 6
    x = _data(n, 4, seed)
    a1 = _cats(n, 3, seed + 30)
    a2 = _cats(n, 2, seed + 60)
    fair = {"a1": a1, "a2": a2}
    dl = np.asarray(anticluster(x, k=k, plan=None, fairness=fair).labels)
    sl = np.asarray(anticluster(x, k=k, plan=None, fairness=fair,
                                chunk_size=100, solver="auction").labels)
    cnt = np.bincount(sl, minlength=k)
    assert cnt.min() >= n // k and cnt.max() <= -(-n // k)
    for a in (a1, a2):
        assert _stratum_spread(sl, a, k) <= max(1, _stratum_spread(dl, a, k))


def test_stream_mask_multichunk_front_door():
    n, k = 512, 8
    x = _data(n, 4, 40)
    vm = np.arange(n) < 470
    res = anticluster(x, k=k, plan=None, valid_mask=vm, chunk_size=128,
                      solver="auction")
    assert int(res.n_valid) == 470
    lab = np.asarray(res.labels)[vm]
    cnt = np.bincount(lab, minlength=k)
    assert cnt.min() >= 470 // k and cnt.max() <= -(-470 // k)


# ---------------------------------------------------------------------------
# routes: hierarchical level 1 and the warm engine
# ---------------------------------------------------------------------------

def test_hierarchical_level1_streams_categories():
    n = 1200
    x = _data(n, 4, 50)
    cats = _cats(n, 3, 51)
    dense = anticluster(x, k=12, max_k=4, categories=cats)
    assert len(dense.plan) > 1
    par = anticluster(x, k=12, max_k=4, categories=cats, chunk_size=n)
    np.testing.assert_array_equal(np.asarray(dense.labels),
                                  np.asarray(par.labels))
    multi = anticluster(x, k=12, max_k=4, categories=cats, chunk_size=256,
                        solver="auction")
    lab = np.asarray(multi.labels)
    cnt = np.bincount(lab, minlength=12)
    assert cnt.min() >= n // 12 and cnt.max() <= -(-n // 12)
    # ceil-of-ceil composition keeps global stratification exact through
    # the hierarchy even when level 1 was chunked
    assert _stratum_spread(lab, cats, 12) <= 1


def test_engine_warm_repartition_streams_fairness():
    n, k = 480, 6
    x0 = _data(n, 4, 60)
    x1 = x0 + 0.05 * _data(n, 4, 61)
    a1 = _cats(n, 3, 62)
    a2 = _cats(n, 2, 63)
    spec = AnticlusterSpec(k=k, plan=None, chunk_size=96, solver="auction",
                           fairness=(a1, a2), stats=False)
    eng = AnticlusterEngine(spec)
    res0, state = eng.partition(x0)
    # the engine's cold pass must equal the one-shot front door bit-for-bit
    one = anticluster(x0, spec)
    np.testing.assert_array_equal(np.asarray(res0.labels),
                                  np.asarray(one.labels))
    res1, state = eng.repartition(x1, state)
    assert eng.compile_count == 1  # warm epoch reused the executable
    lab = np.asarray(res1.labels)
    cnt = np.bincount(lab, minlength=k)
    assert cnt.min() >= n // k and cnt.max() <= -(-n // k)
    for a in (a1, a2):
        assert _stratum_spread(lab, a, k) <= 2


# ---------------------------------------------------------------------------
# loud fallbacks + spec validation
# ---------------------------------------------------------------------------

def test_stacked_auto_chunk_warns_once():
    spec = AnticlusterSpec(k=4, plan=None, chunk_size="auto", stats=False)
    _WARNED_FALLBACKS.clear()
    try:
        with pytest.warns(RuntimeWarning, match="dense core"):
            _route(spec, (2, 70000, 4), False, False)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # second hit must be silent
            _route(spec, (2, 70000, 4), False, False)
    finally:
        _WARNED_FALLBACKS.clear()


def test_stacked_explicit_chunk_still_raises():
    spec = AnticlusterSpec(k=4, plan=None, chunk_size=64, stats=False)
    with pytest.raises(NotImplementedError, match="flat"):
        _route(spec, (2, 70000, 4), False, False)


def test_spec_rejects_categories_plus_fairness():
    cats = _cats(100, 3)
    with pytest.raises(ValueError, match="mutually exclusive"):
        AnticlusterSpec(k=4, categories=cats, fairness=[cats])


def test_spec_rejects_non_integer_fairness():
    with pytest.raises(ValueError, match="integer-coded"):
        AnticlusterSpec(k=4, fairness=[np.linspace(0, 1, 100)])


def test_spec_rejects_mismatched_fairness_lengths():
    with pytest.raises(ValueError, match="disagree on shape"):
        AnticlusterSpec(k=4, fairness=[_cats(100, 3), _cats(90, 2)])
