"""The ``repro.obs`` subsystem: span nesting and thread-safety, the
disabled path's zero-cost contracts (shared no-op span, engine
``compile_count`` pins), solver telemetry parity (stats path bit-identical
to the plain path, dense == stream layouts), memory profiling, the trace
report, and the BenchRecorder schema-collision guard."""

import importlib.util
import json
import pathlib
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro import obs
from repro.anticluster import AnticlusterEngine, AnticlusterSpec
from repro.core.aba import aba_core, aba_stream
from repro.core.assignment import AuctionConfig, auction_solve

REPO = pathlib.Path(__file__).resolve().parent.parent


def _data(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


# ---------------------------------------------------------------------------
# Tracing core
# ---------------------------------------------------------------------------

def test_span_nesting_records_parents():
    clock = FakeClock()
    tr = obs.Trace(clock=clock)
    with tr.span("outer", a=1) as outer:
        clock.advance(1.0)
        with tr.span("inner") as inner:
            clock.advance(0.25)
        assert inner._parent == outer._id
    events = {ev["name"]: ev for ev in tr.snapshot()}
    assert events["inner"]["parent"] == events["outer"]["id"]
    assert events["outer"]["parent"] is None
    assert events["inner"]["dur"] == 0.25
    assert events["outer"]["dur"] == 1.25
    assert events["outer"]["attrs"] == {"a": 1}
    # completion order: inner closes first
    assert [ev["name"] for ev in tr.snapshot()] == ["inner", "outer"]


def test_async_begin_finish_crosses_scopes():
    clock = FakeClock()
    tr = obs.Trace(clock=clock)
    with tr.span("dispatch") as d:
        sp = tr.begin("inflight", k=4)        # parented under "dispatch"
    clock.advance(2.0)
    sp.finish(rounds=7)                       # long after "dispatch" closed
    sp.finish(rounds=99)                      # idempotent: second is a no-op
    events = {ev["name"]: ev for ev in tr.snapshot()}
    assert events["inflight"]["parent"] == d._id
    assert events["inflight"]["dur"] == 2.0
    assert events["inflight"]["attrs"] == {"k": 4, "rounds": 7}
    assert len(tr.snapshot()) == 2


def test_instant_events_and_export_roundtrip(tmp_path):
    tr = obs.Trace(clock=FakeClock())
    with tr.span("parent"):
        tr.event("tick", i=3, arr=jnp.float32(1.5))
    path = str(tmp_path / "t.jsonl")
    assert tr.export_jsonl(path) == 2
    lines = [json.loads(line) for line in open(path)]
    tick = next(ev for ev in lines if ev["name"] == "tick")
    assert tick["dur"] == 0.0
    assert tick["attrs"] == {"i": 3, "arr": 1.5}   # jax scalar -> JSON float
    assert tick["parent"] is not None


def test_thread_safety_under_concurrent_nesting():
    tr = obs.Trace()
    errors = []

    def work(tid):
        try:
            for i in range(50):
                with tr.span(f"outer{tid}") as o:
                    with tr.span(f"inner{tid}") as sp:
                        # the parent must be THIS thread's outer span, never
                        # another thread's (per-thread stacks)
                        assert sp._parent == o._id
                    tr.event(f"ev{tid}", i=i)
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=work, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(tr.snapshot()) == 8 * 50 * 3
    for ev in tr.snapshot():
        if ev["name"].startswith("inner"):
            assert ev["parent"] is not None


def test_disabled_path_is_shared_noop():
    assert not obs.enabled()
    s1, s2 = obs.span("a", x=1), obs.span("b")
    assert s1 is s2                           # one shared allocation-free nop
    with s1 as inside:
        assert inside is s1
    assert s1.set(k=2) is s1
    assert s1.finish() is None
    assert obs.begin("c") is s1
    obs.event("d", x=1)                       # silently dropped
    assert obs.active() is None


def test_tracing_scope_restores_and_exports(tmp_path):
    path = str(tmp_path / "scoped.jsonl")
    prev = obs.enable(obs.Trace())            # an outer trace is active
    try:
        with obs.tracing(path) as tr:
            assert obs.active() is tr and tr is not prev
            with obs.span("only-here"):
                pass
        assert obs.active() is prev           # restored, not disabled
        assert [json.loads(line)["name"]
                for line in open(path)] == ["only-here"]
        assert len(prev.events) == 0          # outer trace untouched
    finally:
        obs.disable()
    assert not obs.enabled()


def test_histogram_exact_percentiles():
    h = obs.Histogram()
    assert h.percentile(50) == 0.0 and h.count == 0 and h.mean == 0.0
    for v in (0.25, 0.75):
        h.record(v)
    assert h.percentile(50) == 0.25           # nearest-rank: ceil(1.0) = 1
    assert h.percentile(99) == 0.75
    assert h.percentile(0) == 0.25 and h.percentile(100) == 0.75
    assert h.count == 2 and h.mean == 0.5
    # bounded ring: old samples age out, count/sum stay lifetime-exact
    small = obs.Histogram(max_samples=2)
    for v in (1.0, 2.0, 3.0):
        small.record(v)
    assert small.count == 3
    assert small.percentile(99) == 3.0 and small.percentile(1) == 2.0
    with pytest.raises(ValueError):
        obs.Histogram(max_samples=0)


def test_trace_report_summarize_and_render(tmp_path):
    spec = importlib.util.spec_from_file_location(
        "trace_report", REPO / "tools" / "trace_report.py")
    trace_report = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(trace_report)

    clock = FakeClock()
    tr = obs.Trace(clock=clock)
    for dur in (0.1, 0.2, 0.3):
        with tr.span("solve"):
            clock.advance(dur)
    tr.event("admit")
    path = str(tmp_path / "r.jsonl")
    tr.export_jsonl(path)

    summary = trace_report.summarize(trace_report.load_events(path))
    s = summary["solve"]
    assert s["count"] == 3 and s["max"] == pytest.approx(0.3)
    assert s["total"] == pytest.approx(0.6) and s["mean"] == pytest.approx(0.2)
    assert s["p50"] == pytest.approx(0.2) and s["p95"] == pytest.approx(0.3)
    assert summary["admit"] == {"count": 1}
    text = trace_report.render(summary)
    assert "solve" in text and "admit" in text


# ---------------------------------------------------------------------------
# Memory profiling
# ---------------------------------------------------------------------------

def test_memory_profile_on_jitted_call():
    x = jnp.asarray(_data(256, 4))
    prof = obs.memory_profile(aba_core, x[None], 4, solver="auction")
    assert isinstance(prof, obs.MemoryProfile)
    if prof.available:                        # CPU builds may lack analysis
        assert prof.temp_bytes >= 0 and prof.total_bytes >= prof.temp_bytes
    else:
        assert prof.temp_bytes == -1 and prof.total_bytes == -1
    # a non-jitted callable has no .lower: honest unavailable, no raise
    bad = obs.memory_profile(lambda a: a, x)
    assert not bad.available


def test_rss_sampling_and_peak():
    assert obs.current_rss_bytes() > 0        # Linux container: /proc works
    assert obs.peak_rss_bytes() >= obs.current_rss_bytes() > 0
    out, peak = obs.sample_rss(lambda: np.zeros(1000), interval_s=0.001)
    assert out.shape == (1000,) and peak > 0
    with obs.rss_sampling(interval_s=0.001) as s:
        np.zeros(10000)
    assert s.peak_bytes > 0 and s.samples >= 1


# ---------------------------------------------------------------------------
# Solver telemetry (the compiled-path stats pytree)
# ---------------------------------------------------------------------------

def test_auction_return_stats_is_parity_preserving():
    rng = np.random.default_rng(3)
    cost = jnp.asarray(rng.normal(size=(3, 24, 24)).astype(np.float32))
    cfg = AuctionConfig()
    plain, p_plain = auction_solve(cost, cfg, return_prices=True)
    out, p_out, stats = auction_solve(cost, cfg, return_stats=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(p_plain), np.asarray(p_out))
    n_phases = stats["rounds"].shape[0]
    assert stats["eps"].shape == (n_phases, 3)
    assert stats["warm"].shape == (3,) and not bool(stats["warm"].any())
    assert int(stats["rounds"].sum()) > 0     # a cold solve does real rounds
    assert not bool(stats["skipped"].any())   # cold: no phase skipping
    # warm re-entry: carried prices shrink the work and mark warm=True
    out_w, p_w, stats_w = auction_solve(cost, cfg, prices=p_out,
                                        return_stats=True)
    assert bool(stats_w["warm"].all())
    assert int(stats_w["rounds"].sum()) <= int(stats["rounds"].sum())


def test_engine_telemetry_bit_identical_and_single_trace():
    x = _data(128, 6, seed=5)
    plain = AnticlusterEngine(AnticlusterSpec(k=4, solver="auction"))
    tele = AnticlusterEngine(AnticlusterSpec(k=4, solver="auction",
                                             telemetry=True))
    r0, s0 = plain.partition(x)
    r1, s1 = tele.partition(x)
    np.testing.assert_array_equal(np.asarray(r0.labels),
                                  np.asarray(r1.labels))
    assert plain.last_telemetry is None
    t = tele.last_telemetry
    assert t is not None and isinstance(t["rounds"], np.ndarray)
    assert int(t["rounds"].sum()) > 0
    r0b, _ = plain.repartition(x, s0)
    r1b, _ = tele.repartition(x, s1)
    np.testing.assert_array_equal(np.asarray(r0b.labels),
                                  np.asarray(r1b.labels))
    # the one-executable contract holds with telemetry riding the output
    assert plain.compile_count == 1 and tele.compile_count == 1
    summary = obs.summarize_auction_telemetry(t)
    assert summary["rounds_total"] == int(t["rounds"].sum())
    assert summary["batches"] * summary["phases"] == t["rounds"].size
    assert obs.summarize_auction_telemetry(None) is None


def test_tracing_adds_no_retrace_and_no_compiled_ops():
    """The headline cost contract: enabling tracing around an engine adds
    host-side spans only -- same executable (no retrace), same labels."""
    x = _data(96, 5, seed=7)
    eng = AnticlusterEngine(AnticlusterSpec(k=4, solver="auction"))
    ref = AnticlusterEngine(AnticlusterSpec(k=4, solver="auction"))
    _, state = eng.partition(x)
    _, ref_state = ref.partition(x)
    assert eng.compile_count == 1
    with obs.tracing() as tr:
        res2, state = eng.repartition(x, state)
    assert eng.compile_count == 1             # no retrace under tracing
    res_ref, _ = ref.repartition(x, ref_state)   # same warm solve, untraced
    np.testing.assert_array_equal(np.asarray(res_ref.labels),
                                  np.asarray(res2.labels))
    names = [ev["name"] for ev in tr.snapshot()]
    assert "engine/repartition" in names
    assert not obs.enabled()                  # scope restored
    # and a traced cold engine compiles exactly once too
    with obs.tracing():
        eng2 = AnticlusterEngine(AnticlusterSpec(k=4, solver="auction"))
        eng2.partition(x)
    assert eng2.compile_count == 1


def test_stream_telemetry_layout_matches_dense():
    x = jnp.asarray(_data(144, 4, seed=9))
    k, chunk = 4, 48
    _, st_d = aba_core(x[None], k, solver="auction", return_state=True,
                       telemetry=True)
    _, st_s = aba_stream(x, k, chunk, solver="auction", return_state=True,
                         telemetry=True)
    td, ts = st_d["telemetry"], st_s["telemetry"]
    assert td is not None and ts is not None
    for key in ("rounds", "eps", "warm", "reentry", "skipped"):
        assert td[key].shape == ts[key].shape, key
    n_batches = x.shape[0] // k
    assert td["rounds"].shape[0] == n_batches - 1


def test_engine_telemetry_unsupported_solver_is_none():
    # greedy has no stats twin: telemetry downgrades to None, never raises
    x = _data(64, 4, seed=11)
    eng = AnticlusterEngine(AnticlusterSpec(k=4, solver="greedy",
                                            telemetry=True))
    res, _ = eng.partition(x)
    assert res.labels.shape == (64,)
    assert eng.last_telemetry is None


# ---------------------------------------------------------------------------
# BenchRecorder schema guard (the satellite bugfix)
# ---------------------------------------------------------------------------

def test_bench_recorder_rejects_schema_colliding_extras():
    from benchmarks.common import BenchRecorder
    rec = BenchRecorder()
    rec.add("b/ok", "8x2", 0.1, 1.0, extra={"peak_bytes": 7})
    assert rec.rows[0]["peak_bytes"] == 7
    with pytest.raises(ValueError, match="wall_s"):
        rec.add("b/bad", "8x2", 0.1, 1.0, extra={"wall_s": 0.0})
    with pytest.raises(ValueError, match="collide"):
        rec.add("b/bad2", "8x2", 0.1, extra={"bench": "x", "note": 1})
    assert len(rec.rows) == 1                 # failed adds record nothing
