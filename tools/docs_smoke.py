"""Execute every fenced ``python`` block in the given markdown files.

The docs are part of the test surface: a README example that no longer runs
is a regression, so CI extracts each ```python fenced block and executes all
of a file's blocks in ONE shared namespace, in order (later blocks may build
on earlier ones, exactly as a reader would run them top to bottom).

A small synthetic prelude provides the free variables the prose leaves to
the reader (``x``, ``x_big``, ``data``, ``embed(...)``, ``fresh_rows``...)
at CI-friendly sizes -- the examples must *run*, not benchmark.  Blocks in
other languages (```sh, ```json) are ignored.  Any exception fails the run
with the offending file, block index and source line.

Usage::

    PYTHONPATH=src python tools/docs_smoke.py README.md docs/ARCHITECTURE.md
"""

from __future__ import annotations

import re
import sys

FENCE = re.compile(r"^```python[ \t]*$(.*?)^```[ \t]*$", re.M | re.S)


def _prelude() -> dict:
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    ns: dict = {
        "np": np,
        "jnp": jnp,
        # the generic working set most blocks share
        "x": rng.normal(size=(1024, 8)).astype(np.float32),
        # the streaming examples' "large" matrix (CI-sized; the prose notes
        # the paper-scale numbers)
        "x_big": rng.normal(size=(8192, 8)).astype(np.float32),
        # incremental-update blocks
        "x0": rng.normal(size=(256, 6)).astype(np.float32),
        "fresh_rows": rng.normal(size=(8, 6)).astype(np.float32),
        # engine / training-loop blocks
        "data": rng.normal(size=(1024, 8)).astype(np.float32),
        "embed": lambda d: jnp.asarray(d, jnp.float32),
        "epochs": 2,
        # serving blocks
        "other_work_first": False,
        "retry_later": lambda reason: None,
        # fairness blocks
        "sites": rng.integers(0, 3, size=1024).astype(np.int32),
        "groups": rng.integers(0, 2, size=1024).astype(np.int32),
    }
    return ns


def run_file(path: str, ns: dict) -> int:
    with open(path) as f:
        text = f.read()
    blocks = FENCE.findall(text)
    for i, block in enumerate(blocks):
        line = text[:text.index(block)].count("\n") + 1
        print(f"# {path} block {i + 1}/{len(blocks)} (line {line})",
              flush=True)
        try:
            exec(compile(block, f"{path}[block {i + 1}]", "exec"), ns)
        except Exception:
            print(f"FAILED: {path} block {i + 1} (starts at line {line})",
                  file=sys.stderr, flush=True)
            raise
    return len(blocks)


def main(paths: list[str]) -> None:
    if not paths:
        sys.exit("usage: docs_smoke.py FILE.md [FILE.md ...]")
    ns = _prelude()  # ONE namespace: files and blocks compose in order
    total = 0
    for p in paths:
        total += run_file(p, ns)
    print(f"# docs smoke OK: {total} python blocks across "
          f"{len(paths)} files")


if __name__ == "__main__":
    main(sys.argv[1:])
