#!/usr/bin/env python
"""Summarize a ``repro.obs`` trace JSONL (span durations grouped by name).

Usage::

    PYTHONPATH=src python tools/trace_report.py TRACE.jsonl [--top N]

One row per span name: count, total/mean/p50/p95/max duration, sorted by
total time.  Instant events (``dur == 0``) are listed separately with their
counts, so a report shows both where time went (spans) and what happened
(admissions, dispatches, solver phases).
"""

from __future__ import annotations

import argparse
import json


def load_events(path: str) -> list[dict]:
    """Parse one trace event per JSONL line (blank lines ignored)."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def _pct(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile of a pre-sorted non-empty list."""
    n = len(sorted_vals)
    rank = max(1, min(n, -(-int(q * n) // 100)))
    return sorted_vals[rank - 1]


def summarize(events: list[dict]) -> dict[str, dict]:
    """Per-name duration statistics over the span events.

    Returns ``{name: {count, total, mean, p50, p95, max}}`` for spans and
    ``{name: {count}}`` (no duration keys) for instant events; the split is
    on recorded duration (an event records ``dur == 0`` by construction).
    """
    spans: dict[str, list[float]] = {}
    instants: dict[str, int] = {}
    for ev in events:
        dur = float(ev.get("dur", 0.0))
        name = ev.get("name", "?")
        if dur > 0.0:
            spans.setdefault(name, []).append(dur)
        else:
            instants[name] = instants.get(name, 0) + 1
    out: dict[str, dict] = {}
    for name, durs in spans.items():
        durs.sort()
        total = sum(durs)
        out[name] = {
            "count": len(durs), "total": total,
            "mean": total / len(durs),
            "p50": _pct(durs, 50), "p95": _pct(durs, 95),
            "max": durs[-1],
        }
    for name, count in instants.items():
        out.setdefault(name, {"count": count})
    return out


def render(summary: dict[str, dict], top: int | None = None) -> str:
    """The report table as a string (span rows first, by total desc)."""
    spans = [(n, s) for n, s in summary.items() if "total" in s]
    instants = [(n, s) for n, s in summary.items() if "total" not in s]
    spans.sort(key=lambda it: -it[1]["total"])
    instants.sort(key=lambda it: -it[1]["count"])
    if top is not None:
        spans = spans[:top]
    lines = [f"{'span':<28} {'count':>6} {'total_s':>10} {'mean_s':>10} "
             f"{'p50_s':>10} {'p95_s':>10} {'max_s':>10}"]
    for name, s in spans:
        lines.append(
            f"{name:<28} {s['count']:>6} {s['total']:>10.4f} "
            f"{s['mean']:>10.5f} {s['p50']:>10.5f} {s['p95']:>10.5f} "
            f"{s['max']:>10.5f}")
    if instants:
        lines.append("")
        lines.append(f"{'event':<28} {'count':>6}")
        for name, s in instants:
            lines.append(f"{name:<28} {s['count']:>6}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace JSONL written by obs.tracing()")
    ap.add_argument("--top", type=int, default=None,
                    help="show only the N hottest span names")
    args = ap.parse_args(argv)
    events = load_events(args.trace)
    print(f"# {len(events)} events from {args.trace}")
    print(render(summarize(events), top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
