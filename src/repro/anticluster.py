"""The spec-driven front door for Euclidean anticlustering.

Two public surfaces over one rank-polymorphic core:

* :func:`anticluster` -- the one-shot call.  ``anticluster(x, spec)`` is
  semantically ``AnticlusterEngine(spec).partition(x)[0]`` (a parity test
  pins the two bit-for-bit) but dispatches straight to the module-level
  jitted cores, so repeated one-shot calls share the global compile cache.

    from repro.anticluster import AnticlusterSpec, anticluster

    res = anticluster(x, AnticlusterSpec(k=500))          # flat or auto-plan
    res = anticluster(x, k=500, plan=(10, 50))            # explicit hierarchy
    res = anticluster(x, k=5, categories=y)               # stratified (4.3)
    res = anticluster(x, k=512, mesh=mesh)                # shard_map across mesh
    res.labels, res.plan, res.cluster_sizes, res.balanced # result pytree

* :class:`AnticlusterEngine` -- the session API for the paper's *repeated*
  workloads (a fresh mini-batch partition every training epoch,
  representative K-fold CV, request serving).  The engine compiles one
  shape-keyed executable per input signature (state buffers donated) and
  carries an explicit :class:`ABAState` pytree -- the auction's dual prices
  per hierarchy level, the centrality running moments, and the previous
  labels -- so ``engine.repartition(x, state)`` warm-starts every
  epsilon-scaling auction instead of re-discovering the price equilibrium
  from zero:

    engine = AnticlusterEngine(AnticlusterSpec(k=64))
    res, state = engine.partition(x)            # compiles once for x.shape
    for epoch in range(E):
        x = embed(data)                         # same shape, drifted values
        res, state = engine.repartition(x, state)   # zero retrace, warm solve

``anticluster`` routes flat -> streaming -> hierarchical -> sharded
execution from the spec alone; every regime runs on the ONE rank-polymorphic
masked core (``repro.core.aba.aba_core``) so there is exactly one
implementation of the centrality sort / padding / Algorithm-1 scan.  At
million-object scale (``chunk_size="auto"`` or an explicit int) the flat
level runs through the chunked matrix-free twin ``repro.core.aba.aba_stream``
(same per-batch step, O(chunk*d + k*d) working set, bit-identical labels
when ``chunk_size >= n``).  The LAP backend is looked up
in the solver registry (``register_solver`` / ``get_solver``), so new
backends are a registry entry, not a seventh entry point.

``anticluster`` itself is a host-level convenience (it builds the result
statistics eagerly); inside ``jit``/``scan``/``shard_map`` call the cores
directly (``aba_core`` / ``hierarchical_core`` / ``sharded_core``).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.aba import aba_core, aba_stream
from repro.core.assignment import (AuctionConfig, available_solvers,
                                   get_solver, register_solver)
from repro.core.hierarchical import (default_plan, hierarchical_core,
                                     plan_price_shapes)
from repro.core.kplus import kplus_augment
from repro.sharding.specs import resolve_data_axes, shard_leading

__all__ = [
    "AnticlusterSpec", "AnticlusterResult", "anticluster",
    "AnticlusterEngine", "ABAState", "ShardedABAState",
    "PendingRepartition",
    "register_solver", "get_solver", "available_solvers",
]

# Streaming auto-selection thresholds: below _AUTO_STREAM_MIN rows the dense
# core's one-shot gather is cheap and ``chunk_size="auto"`` stays flat; at or
# above it the streaming core engages with ~_AUTO_CHUNK_ROWS rows per chunk
# (rounded to a multiple of k inside ``aba_stream``), keeping the working
# set O(chunk*d + k*d) regardless of n.
_AUTO_STREAM_MIN = 1 << 16   # 65536 rows
_AUTO_CHUNK_ROWS = 1 << 13   # 8192 rows per chunk


@dataclasses.dataclass(frozen=True, eq=False)
class AnticlusterSpec:
    """Frozen configuration for :func:`anticluster`.

    Attributes:
      k: number of anticlusters (required).
      variant: "auto" | "base" | "interleave" (paper Section 4.2; "auto"
        interleaves when anticlusters are small, n/k <= 8).
      categories: optional (n,) int category labels -- Section 4.3 exact
        stratification.  Composes with hierarchy: every level stratifies
        within its groups, and the global constraint (5) still holds exactly
        (ceil/floor compose across levels, see ``repro.core.hierarchical``).
      n_categories: static category count; 0 infers it from ``categories``.
      fairness: proportional fairness over one or more protected attributes
        -- the multi-attribute generalization of constraint (5).  Takes a
        single int attribute array (exactly the ``categories=`` constraint,
        bit-for-bit), a dict / list / tuple of several, or a stacked
        ``(n, A)`` int array (last axis = attributes).  With several
        attributes the *joint* attribute cell drives the Section 4.3
        rearrangement and every cluster is capped at
        ``ceil(|N_av| / k)`` members of each attribute value ``av``
        independently, so each cluster's attribute marginals track the
        population's proportions.  Multi-attribute caps are best-effort
        where attribute transversals conflict (the LAP must place k rows in
        distinct clusters per batch; an infeasible quota combination
        overflows by at most the conflicting rows -- single-attribute
        fairness is exact).  Mutually exclusive with ``categories=``;
        streams, shards and composes everywhere categories do.
      solver: LAP backend name in the solver registry ("auction",
        "auction_fused", "greedy", "scipy", or anything you
        ``register_solver``-ed).
      auction_config: epsilon-scaling schedule for the auction backends.
      plan: hierarchy plan (Section 4.4).  ``"auto"`` factorizes k with
        ``default_plan`` (every factor <= ``max_k``); a tuple is used as-is
        (must multiply to k); ``None`` forces the flat single-level path.
      chunk_size: streaming execution (million-scale path).  ``None`` keeps
        the dense one-shot core; an int streams the centrality-sorted object
        list through ``repro.core.aba.aba_stream`` in chunks of that many
        rows (peak live memory O(chunk_size*d + k*d) beyond the input);
        ``"auto"`` streams only at scale (n >= 65536 rows, ~8192-row chunks)
        and additionally upgrades the default "auction" solver to
        "auction_fused" so each batch LAP is matrix-free (the (k, k) value
        matrix is never built -- the paper's Tables 8/10 operating range).
        Applies to the flat path, the first (full-data) hierarchical level,
        and each shard's local solve under ``mesh``.  Categories, fairness
        and valid_mask all stream (the Section 4.3 rearrangement runs as a
        single chunked rank-in-category pass, the quota counts ride the
        assignment scan); only stacked (G, M, D) input stays dense -- an
        explicit int raises there, ``"auto"`` falls back with a
        ``RuntimeWarning`` (once per route) naming the reason.  With
        ``chunk_size >= n`` labels are bit-for-bit identical to the dense
        path.
      max_k: largest admissible LAP size for the auto plan.
      mesh: optional ``jax.sharding.Mesh`` -- an orthogonal *placement* axis
        of the same API, not a separate mode: execution routes through
        ``shard_map`` (the data sharding becomes the first hierarchy level),
        composing with streaming (each shard runs ``aba_stream`` on its
        local rows), categories / valid_mask (each shard stratifies / masks
        its local rows; the mask needs a flat per-shard plan), and the
        engine's warm starts (:class:`ShardedABAState`).  ``k`` and ``n``
        must be divisible by the shard count of ``data_axes``.
      data_axes: mesh axes that shard the data.  ``"auto"`` (default) takes
        whichever of ('pod', 'data') exist on the mesh; an explicit tuple is
        validated strictly -- naming an axis the mesh does not have raises
        with the offending names instead of silently dropping them.
      valid_mask: optional bool mask marking padding rows (shape of labels);
        masked rows get arbitrary labels in [0, k).
      kplus_moments: >= 2 augments features with standardized centered
        moments (k-plus, Section 3.3) before clustering; flat unmasked
        (n, d) input only.
      dtype: feature dtype fed to the core (the core computes in float32).
      batched: False switches hierarchical levels to the legacy vmap of
        per-group solves (identical labels; exists for benchmarking).
      stats: False skips the diversity statistics (sd/range report 0) so
        timed benchmark windows measure only the solve + cluster sizes.
        ``stats=True`` additionally surfaces the auction duals as an
        optimality-gap certificate (``AnticlusterResult.dual_bound`` /
        ``gap``; meshless modes only, computed outside any timed path).
      update_threshold: largest delta fraction ``(added + removed) / n_new``
        that :meth:`AnticlusterEngine.update` absorbs incrementally via the
        restricted frozen-price auction; a larger delta falls back -- loudly,
        with a ``RuntimeWarning`` -- to a full warm ``repartition``
        (bit-for-bit identical to calling ``repartition`` on the post-delta
        data with the carried prices).
      telemetry: surface the auction solver's internals (rounds per eps
        phase, the eps schedule, warm re-entry decisions) from the compiled
        path: the engine's result carries the stacked per-batch stats
        pytree (``AnticlusterEngine.last_telemetry``; converted to NumPy at
        ``wait()``, outside any timed window) and a traced run
        (``repro.obs``) records per-phase ``solver/phase`` events.  Flat,
        stream, and stacked routes report; hierarchical and mesh routes
        report ``None`` (their per-level/per-shard solves are not
        stitchable into one batch axis).  Solvers without a registered
        stats twin (greedy, scipy) report ``None`` as well.  The flag is a
        static part of the compiled signature: ``telemetry=False`` (the
        default) leaves every executable byte-identical -- observability
        never taxes the default path.
    """

    k: int
    variant: str = "auto"
    categories: Any = None
    n_categories: int = 0
    fairness: Any = None
    solver: str = "auction"
    auction_config: AuctionConfig = AuctionConfig()
    plan: Any = "auto"
    chunk_size: Any = None
    max_k: int = 512
    mesh: Any = None
    data_axes: Any = "auto"
    valid_mask: Any = None
    kplus_moments: int = 1
    dtype: Any = jnp.float32
    batched: bool = True
    stats: bool = True
    update_threshold: float = 0.25
    telemetry: bool = False

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k={self.k} must be >= 1")
        if not 0.0 <= self.update_threshold <= 1.0:
            raise ValueError(
                f"update_threshold={self.update_threshold} must be in "
                "[0, 1] (the delta fraction above which update() falls "
                "back to a full repartition)")
        if isinstance(self.plan, tuple) and math.prod(self.plan) != self.k:
            raise ValueError(
                f"prod(plan)={math.prod(self.plan)} != k={self.k}")
        if self.plan is not None and not isinstance(self.plan, tuple) \
                and self.plan != "auto":
            raise ValueError(f'plan must be "auto", a tuple, or None; '
                             f"got {self.plan!r}")
        if self.chunk_size is not None and self.chunk_size != "auto" and \
                (not isinstance(self.chunk_size, int)
                 or self.chunk_size < 1):
            raise ValueError(f'chunk_size must be None, "auto", or a '
                             f"positive int; got {self.chunk_size!r}")
        if self.fairness is not None:
            if self.categories is not None:
                raise ValueError(
                    "categories= and fairness= are mutually exclusive "
                    "(single-attribute fairness IS the categories= "
                    "constraint -- pass just one of them)")
            _fairness_attrs(self.fairness)  # validate shape/dtype up front

    def evolve(self, **changes) -> "AnticlusterSpec":
        """A new spec with ``changes`` applied -- the supported public
        alternative to raw ``dataclasses.replace``.

        Validates the *field names* up front (an unknown name raises
        ``TypeError`` listing the valid fields, instead of
        ``dataclasses.replace``'s bare complaint) and re-runs the frozen
        spec's ``__post_init__`` checks (k/plan consistency, chunk_size
        domain) on the evolved value.  Every keyword-``overrides`` surface
        in the repo (``anticluster(x, spec, **ov)``,
        ``AnticlusterEngine(spec, **ov)``, the serving tier, the
        folds/minibatch spec derivation) routes through here, so "spec +
        overrides" means exactly one thing everywhere.
        """
        if not changes:
            return self
        valid = {f.name for f in dataclasses.fields(self)}
        unknown = sorted(set(changes) - valid)
        if unknown:
            raise TypeError(
                f"unknown AnticlusterSpec field(s) {unknown}; valid fields "
                f"are {sorted(valid)}")
        return dataclasses.replace(self, **changes)

    def replace(self, **overrides) -> "AnticlusterSpec":
        """Back-compat alias of :meth:`evolve` (same validation)."""
        return self.evolve(**overrides)

    def resolve_plan(self) -> tuple[int, ...]:
        """The concrete per-device hierarchy plan this spec dispatches to."""
        if self.plan is None:
            return (self.k,)
        if isinstance(self.plan, tuple):
            return self.plan
        k = self.k
        if self.mesh is not None:
            n_shards = _mesh_shards(self)
            if k % n_shards:
                raise ValueError(
                    f"k={k} must be divisible by shard count {n_shards}")
            k = k // n_shards
        return default_plan(k, max_k=self.max_k)

    def resolve_chunk(self, n: int, k: int) -> int | None:
        """Concrete per-level chunk size for ``n`` rows, or None (dense).

        ``k`` is the level's anticluster count (the chunk is rounded to a
        multiple of it inside ``aba_stream``); "auto" engages only when the
        level is large enough for chunking to pay for itself.
        """
        if self.chunk_size is None:
            return None
        if self.chunk_size == "auto":
            if n < _AUTO_STREAM_MIN:
                return None
            return max(k, _AUTO_CHUNK_ROWS)
        return int(self.chunk_size)


@dataclasses.dataclass(frozen=True)
class AnticlusterResult:
    """Labels plus the resolved execution plan and quality statistics.

    A pytree: ``labels`` / ``cluster_sizes`` / ``diversity_sd`` /
    ``diversity_range`` / ``dual_bound`` / ``gap`` are leaves, the resolved
    ``plan`` and the spec echoes (``k``, ``solver``, ``variant``) plus the
    ``updated`` provenance flag are static metadata.  For stacked (G, M, D)
    inputs every field carries the leading group axis.

    ``dual_bound`` / ``gap`` (``spec.stats=True``, meshless modes) are the
    LP-dual optimality certificate built from the auction's carried duals
    (see :func:`repro.core.objective.dual_certificate`): ``dual_bound``
    upper-bounds the best assignment objective at the realized centroids and
    ``gap >= 0`` is its relative distance from the achieved objective --
    near-zero certifies the assignment step converged.  ``None`` when stats
    are off, under a mesh, or for zero-price (non-auction) solves where only
    the trivial bound is available (still reported -- it is valid for any
    prices, just loose).

    ``updated`` is True only for results produced by the incremental path of
    :meth:`AnticlusterEngine.update` (the restricted frozen-price auction);
    full solves -- including update()'s loud over-threshold fallback --
    report False.
    """

    labels: jnp.ndarray          # (n,) or (G, M) int32 in [0, k)
    cluster_sizes: jnp.ndarray   # (k,) or (G, k) int32 (valid rows only)
    diversity_sd: jnp.ndarray    # () or (G,) std of per-cluster diversity
    diversity_range: jnp.ndarray  # () or (G,) max - min of the same
    k: int = 1
    plan: tuple[int, ...] = ()
    solver: str = "auction"
    variant: str = "auto"
    dual_bound: Any = None       # () or (G,) LP-dual bound (stats=True)
    gap: Any = None              # () or (G,) relative optimality gap
    updated: bool = False        # True only for incremental update() results

    @property
    def n_valid(self):
        """Number of non-padding rows (per group for stacked inputs)."""
        return np.asarray(self.cluster_sizes).sum(axis=-1)

    @property
    def balanced(self) -> bool:
        """Constraint (2): all sizes in {floor(n/k), ceil(n/k)} (Prop. 1)."""
        sizes = np.asarray(self.cluster_sizes)
        n = sizes.sum(axis=-1, keepdims=True)
        return bool(np.all(sizes >= n // self.k)
                    and np.all(sizes <= -(-n // self.k)))


jax.tree_util.register_dataclass(
    AnticlusterResult,
    data_fields=["labels", "cluster_sizes", "diversity_sd",
                 "diversity_range", "dual_bound", "gap"],
    meta_fields=["k", "plan", "solver", "variant", "updated"])


@dataclasses.dataclass(frozen=True)
class ABAState:
    """The carried solver state of one anticlustering session.

    A pure-array pytree (jit/``device_put``/pickle-safe; every field is a
    leaf, there is no static metadata), produced by
    ``AnticlusterEngine.partition`` / ``repartition`` and consumed by
    ``repartition`` to warm-start the next same-shape solve:

    * ``prices`` -- the auction's dual price vectors, one per hierarchy
      level (level l is ``(prod(plan[:l-1]), plan[l-1])`` float32; flat,
      streamed and stacked runs carry a 1-tuple).  These are shift-invariant
      (the engine re-centers them per group), and a zeroed tuple is exactly
      the cold start: ``repartition`` with ``init_state``'s zeros is
      bit-identical to ``partition``.
    * ``moment_sum`` / ``moment_count`` -- the running centrality moments
      (per-group feature sums and valid-row counts) behind the level-1
      centrality sort; mergeable across sessions the way ``aba_stream``
      merges its chunk moments.
    * ``prev_labels`` -- the previous assignment ((n,) or (G, M) int32;
      ``-1`` before the first partition).
    """

    prices: tuple[jnp.ndarray, ...]
    moment_sum: jnp.ndarray
    moment_count: jnp.ndarray
    prev_labels: jnp.ndarray


jax.tree_util.register_dataclass(
    ABAState,
    data_fields=["prices", "moment_sum", "moment_count", "prev_labels"],
    meta_fields=[])


@dataclasses.dataclass(frozen=True)
class ShardedABAState:
    """The carried state of a *distributed* anticlustering session.

    The mesh twin of :class:`ABAState` -- same role, per-shard layout.  A
    pure-array pytree produced/consumed by an :class:`AnticlusterEngine`
    whose spec carries a ``mesh``; every leaf shards its **leading axis**
    across the spec's data axes (``jax.sharding.NamedSharding``, see
    ``AnticlusterEngine.state_shardings``), so ``repartition`` threads it
    straight through one ``shard_map`` executable with zero resharding:

    * ``prices`` -- per-shard, per-level auction dual price stacks: level l
      of the per-shard plan is ``(n_shards, prod(plan[:l-1]), plan[l-1])``
      float32.  A zeroed tuple is exactly the cold start (bit-identical to
      the one-shot ``anticluster(x, spec)`` mesh path).
    * ``moment_sum`` / ``moment_count`` -- (n_shards, d) per-shard feature
      sums over valid rows and (n_shards,) valid-row counts (the shard-local
      centrality moments; summing over the shard axis gives the global
      moments an :class:`ABAState` would carry).
    * ``prev_labels`` -- the previous global assignment ((n,) int32,
      row-sharded; ``-1`` before the first partition).
    """

    prices: tuple[jnp.ndarray, ...]
    moment_sum: jnp.ndarray
    moment_count: jnp.ndarray
    prev_labels: jnp.ndarray


jax.tree_util.register_dataclass(
    ShardedABAState,
    data_fields=["prices", "moment_sum", "moment_count", "prev_labels"],
    meta_fields=[])


def _resolve_spec(spec: "AnticlusterSpec | None",
                  overrides: dict) -> "AnticlusterSpec":
    """The one "spec or keyword overrides" rule every front door shares.

    ``None`` builds a fresh spec from the overrides; an existing spec is
    evolved through the validated :meth:`AnticlusterSpec.evolve`.
    """
    if spec is None:
        return AnticlusterSpec(**overrides)
    return spec.evolve(**overrides)


def _mesh_shards(spec: "AnticlusterSpec") -> int:
    """Total data-parallel shard count for the spec's mesh (1 if no mesh).

    Validates ``spec.data_axes`` against the mesh: explicit axes absent from
    the mesh raise (with the offending names) instead of being dropped.
    """
    if spec.mesh is None:
        return 1
    axes = resolve_data_axes(spec.mesh, spec.data_axes)
    return math.prod(spec.mesh.shape[a] for a in axes)


def _fairness_attrs(fairness) -> list:
    """Normalize ``AnticlusterSpec.fairness`` to a list of integer attribute
    arrays (one per protected attribute), validating as it goes.

    Accepted forms: a dict (attribute name -> codes; insertion order), a
    list/tuple of arrays, a single 1-D array/sequence, or a stacked 2-D
    ``(n, A)`` array whose last axis is the attribute axis.  (For stacked
    (G, M, D) inputs pass a list/dict of (G, M) arrays -- a bare 2-D array
    is always read as (n, A).)
    """
    if isinstance(fairness, dict):
        items = list(fairness.values())
    elif isinstance(fairness, (list, tuple)):
        items = list(fairness)
        if items and np.ndim(items[0]) == 0:
            items = [fairness]  # one attribute given as a plain sequence
    else:
        arr = np.asarray(fairness)
        items = ([arr[..., a] for a in range(arr.shape[-1])]
                 if arr.ndim == 2 else [arr])
    if not items:
        raise ValueError("fairness= needs at least one attribute")
    attrs = []
    for a, item in enumerate(items):
        arr = np.asarray(item)
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(
                f"fairness attribute {a} must be integer-coded, got dtype "
                f"{arr.dtype} (encode the levels as 0..C-1)")
        if arr.size and int(arr.min()) < 0:
            raise ValueError(f"fairness attribute {a} has negative codes")
        if attrs and arr.shape != attrs[0].shape:
            raise ValueError(
                f"fairness attributes disagree on shape: {arr.shape} vs "
                f"{attrs[0].shape}")
        attrs.append(arr)
    return attrs


def _resolve_constraints(spec: "AnticlusterSpec"):
    """``(categories, n_categories, fair_codes, n_fair_codes)`` as the cores
    take them, from either ``spec.categories`` or ``spec.fairness``.

    One attribute (or plain ``categories=``) resolves to the exact
    constraint-(5) path (``fair_codes`` stays None -- bit-for-bit the
    categorical core).  Several attributes resolve to the *joint* mixed-radix
    cell as the rearrangement category plus per-attribute offset codes into
    one shared ``sum(C_a)``-wide quota axis (see ``aba_core``'s
    ``fair_codes``).
    """
    if spec.fairness is None:
        cats = spec.categories
        n_categories = spec.n_categories
        if cats is not None:
            cats = jnp.asarray(cats, jnp.int32)
            if n_categories <= 0:
                n_categories = int(np.asarray(cats).max()) + 1
        return cats, n_categories, None, 0
    attrs = _fairness_attrs(spec.fairness)
    sizes = [int(a.max()) + 1 if a.size else 1 for a in attrs]
    if len(attrs) == 1:
        # one attribute degenerates to the exact categories= constraint
        return jnp.asarray(attrs[0], jnp.int32), sizes[0], None, 0
    joint = np.zeros(attrs[0].shape, np.int64)
    for a, s in zip(attrs, sizes):
        joint = joint * s + a
    offs = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    codes = np.stack([a + o for a, o in zip(attrs, offs)], axis=-1)
    return (jnp.asarray(joint, jnp.int32), int(np.prod(sizes)),
            jnp.asarray(codes, jnp.int32), int(sum(sizes)))


_WARNED_FALLBACKS: set = set()


def _warn_dense_fallback(key, msg: str) -> None:
    """RuntimeWarning (once per route key) for a silent-degradation point.

    Streaming fallbacks change *memory*, not labels, so they warn instead of
    raising -- but only once per distinct route, so a per-epoch engine loop
    does not spam.  docs/ARCHITECTURE.md's fallback matrix lists every
    caller.
    """
    if key in _WARNED_FALLBACKS:
        return
    _WARNED_FALLBACKS.add(key)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _route(spec: AnticlusterSpec, shape: tuple[int, ...],
           has_categories: bool, has_valid_mask: bool):
    """Static dispatch decisions shared by ``anticluster()`` and the engine.

    Returns ``(mode, plan, solver, chunk)``: ``mode`` in ``"mesh"`` |
    ``"stacked"`` | ``"hier"`` | ``"stream"`` | ``"flat"``; ``solver`` the
    resolved registry name (the at-scale auto upgrade applied); ``chunk``
    the concrete per-level row count or None.  One function, so the engine
    and the one-shot wrapper can never disagree on the execution route.
    """
    if len(shape) not in (2, 3):
        raise ValueError(f"x must be (n, d) or (G, M, D), got {shape}")
    plan = spec.resolve_plan()
    streamable = len(shape) == 2  # categories/fairness/valid_mask all stream
    if spec.chunk_size is not None and not streamable \
            and spec.chunk_size != "auto":
        raise NotImplementedError(
            "chunk_size streaming needs flat (n, d) input; stacked "
            '(G, M, D) batches stay dense (chunk_size="auto" falls back '
            "loudly) -- split the groups into flat calls to stream them")
    if spec.chunk_size is not None and len(shape) == 3 \
            and shape[1] >= _AUTO_STREAM_MIN:
        _warn_dense_fallback(
            ("stacked", shape[1]),
            f"chunk_size streaming does not apply to stacked (G, M, D) "
            f"input; running the dense core on {shape} (split the groups "
            "into flat anticluster() calls to stream them)")

    def chunk_for(n_level: int, k_level: int) -> int | None:
        return spec.resolve_chunk(n_level, k_level) if streamable else None

    n = shape[0]
    solver = spec.solver
    if spec.chunk_size == "auto" and solver == "auction" and streamable \
            and not has_categories:
        # (with categories the quota mask can't be factored -- _assign_batch
        # would fall back to the fused solver's dense solve anyway, so the
        # plain auction stays the stratified default)
        n_level = n // max(_mesh_shards(spec), 1)
        if chunk_for(n_level, plan[0]) is not None:
            # at scale the matrix-free factored auction is the default engine
            solver = "auction_fused"

    if spec.mesh is not None:
        if len(shape) != 2:
            raise NotImplementedError(
                "mesh execution takes flat (n, d) data (shards are the "
                "first hierarchy level); stack the groups yourself or drop "
                "the mesh")
        if spec.plan != "auto":
            raise NotImplementedError(
                'mesh execution resolves its per-shard plan from max_k; '
                'use plan="auto"')
        n_shards = _mesh_shards(spec)
        if n % max(n_shards, 1):
            raise ValueError(
                f"n={n} rows must be divisible by the mesh shard count "
                f"{n_shards} (pad the dataset and mark the padding with "
                "valid_mask)")
        if has_valid_mask and len(plan) > 1:
            raise NotImplementedError(
                f"valid_mask under a mesh needs a flat per-shard plan (got "
                f"{plan}); raise max_k or drop the padding rows")
        return "mesh", plan, solver, chunk_for(n // max(n_shards, 1), plan[0])
    if len(shape) == 3:
        if len(plan) > 1:
            raise NotImplementedError(
                "stacked (G, M, D) input requires a flat plan "
                f"(got plan={plan}); hierarchy nests via repeated calls")
        return "stacked", plan, solver, None
    if len(plan) > 1:
        if has_valid_mask:
            raise NotImplementedError(
                "hierarchical plans do not support valid_mask; drop the "
                "padding rows instead")
        return "hier", plan, solver, chunk_for(n, plan[0])
    chunk = chunk_for(n, spec.k)
    return ("stream" if chunk is not None else "flat"), plan, solver, chunk


def _call_core(x, spec: AnticlusterSpec, mode: str, plan, solver: str,
               chunk, cats, n_categories: int, vm, codes=None,
               n_codes: int = 0, prices=None, return_state: bool = False,
               telemetry: bool = False):
    """Dispatch one solve to the right core (shared engine/one-shot path).

    ``prices`` is the per-level tuple from :class:`ABAState` (flat /
    streamed / stacked runs use a 1-tuple) or, in mesh mode, the per-shard
    stacks from :class:`ShardedABAState`; ``None`` is the cold path and is
    bit-identical.  ``codes`` / ``n_codes`` are the multi-attribute fairness
    quota codes from :func:`_resolve_constraints` (None for plain categories
    / single-attribute fairness).  With ``return_state`` the return is
    ``(labels, state)`` where ``state["prices"]`` is the per-level tuple and
    ``state["mu"]`` the level-1 centrality centroid ((d,); (G, d) for
    stacked input) -- except in mesh mode, where the state carries the
    per-shard moments directly (``"moment_sum"`` (S, d) /
    ``"moment_count"`` (S,)).

    ``telemetry`` (static, requires ``return_state``) adds a ``"telemetry"``
    key to the state dict: the solver's per-batch stats pytree for the
    flat / stream / stacked routes, ``None`` for hier / mesh (their
    per-level / per-shard solves have no single batch axis) and for
    solvers without a stats twin.
    """
    kw = dict(variant=spec.variant, solver=solver,
              auction_config=spec.auction_config)
    if mode == "mesh":
        from repro.core.sharded import sharded_core
        out = sharded_core(
            x, spec.k, spec.mesh, data_axes=spec.data_axes,
            max_k=spec.max_k, batched=spec.batched, chunk_size=chunk,
            categories=cats, n_categories=n_categories,
            fair_codes=codes, n_fair_codes=n_codes, valid_mask=vm,
            prices=prices, return_state=return_state, **kw)
        if return_state and telemetry:
            out[1]["telemetry"] = None  # per-shard solves: no batch axis
        return out
    p0 = None if prices is None else prices[0]
    if mode == "stacked":
        out = aba_core(x, spec.k, vm, categories=cats,
                       n_categories=n_categories, fair_codes=codes,
                       n_fair_codes=n_codes, prices=p0,
                       return_state=return_state, telemetry=telemetry, **kw)
        if not return_state:
            return out
        labels, st = out
        state = {"prices": (st["prices"],), "mu": st["mu"]}
        if telemetry:
            state["telemetry"] = st["telemetry"]
        return labels, state
    if mode == "hier":
        out = hierarchical_core(x, plan, categories=cats,
                                n_categories=n_categories,
                                fair_codes=codes, n_fair_codes=n_codes,
                                batched=spec.batched, chunk_size=chunk,
                                prices=prices, return_state=return_state,
                                **kw)
        if return_state and telemetry:
            out[1]["telemetry"] = None  # per-level solves: no batch axis
        return out
    if mode == "stream":
        out = aba_stream(x, spec.k, chunk, categories=cats,
                         n_categories=n_categories, fair_codes=codes,
                         n_fair_codes=n_codes, valid_mask=vm, prices=p0,
                         return_state=return_state, telemetry=telemetry,
                         **kw)
        if not return_state:
            return out
        labels, st = out
        state = {"prices": (st["prices"],), "mu": st["mu"]}
        if telemetry:
            state["telemetry"] = st["telemetry"]
        return labels, state
    # flat: the G=1 specialization of the stacked core
    out = aba_core(x[None], spec.k, None if vm is None else vm[None],
                   categories=None if cats is None else cats[None],
                   n_categories=n_categories,
                   fair_codes=None if codes is None else codes[None],
                   n_fair_codes=n_codes, prices=p0,
                   return_state=return_state, telemetry=telemetry, **kw)
    if not return_state:
        return out[0]
    labels, st = out
    state = {"prices": (st["prices"],), "mu": st["mu"][0]}
    if telemetry:
        state["telemetry"] = st["telemetry"]
    return labels[0], state


def _result_stats(x, labels, k, valid_mask, diversity=True):
    """Masked per-group (sizes, diversity sd, diversity range).

    The masked/grouped generalization of ``repro.core.objective``'s
    ``cluster_sizes`` / ``diversity_stats`` (which stay the flat fast path);
    a drift guard in tests/test_anticluster.py pins the two to each other.
    """
    squeeze = x.ndim == 2
    if squeeze:
        x, labels = x[None], labels[None]
        valid_mask = None if valid_mask is None else valid_mask[None]
    G, M, D = x.shape
    w = (jnp.ones((G, M), jnp.float32) if valid_mask is None
         else valid_mask.astype(jnp.float32))
    seg = labels + k * jnp.arange(G, dtype=labels.dtype)[:, None]
    seg = jnp.where(w > 0, seg, G * k)  # padding rows -> dump segment
    sizes = jax.ops.segment_sum(
        w.reshape(-1), seg.reshape(-1), num_segments=G * k + 1
    )[:G * k].reshape(G, k).astype(jnp.int32)
    if not diversity:
        zero = jnp.zeros((G,), jnp.float32)
        return (sizes[0], zero[0], zero[0]) if squeeze else (sizes, zero,
                                                             zero)
    sums = jax.ops.segment_sum(
        (x * w[..., None]).reshape(-1, D), seg.reshape(-1),
        num_segments=G * k + 1)[:G * k].reshape(G, k, D)
    mu = sums / jnp.maximum(sizes, 1).astype(jnp.float32)[..., None]
    sq = jnp.sum((x - jnp.take_along_axis(
        mu, labels[..., None], axis=1)) ** 2, axis=-1) * w
    div = jax.ops.segment_sum(
        sq.reshape(-1), seg.reshape(-1), num_segments=G * k + 1
    )[:G * k].reshape(G, k)
    sd = jnp.std(div, axis=1)
    rng = jnp.max(div, axis=1) - jnp.min(div, axis=1)
    if squeeze:
        return sizes[0], sd[0], rng[0]
    return sizes, sd, rng


def _cluster_prices(prices: tuple, mode: str):
    """Per-global-cluster duals from a carried per-level price tuple.

    Flat/streamed runs carry a ``(1, k)`` 1-tuple; hierarchical runs a
    per-level tuple whose *last* level is ``(prod(plan[:-1]), k_last)`` --
    global labels compose as ``g * k_last + sub`` (see
    ``repro.core.hierarchical``), so a row-major reshape is exactly
    global-cluster order.  Stacked runs keep their ``(G, k)`` group axis.
    Prices are re-centered per group first (idempotent for engine states,
    which are already re-centered; the duals are shift-invariant).
    """
    last = prices[-1]
    last = last - jnp.max(last, axis=-1, keepdims=True)
    return last if mode == "stacked" else last.reshape(-1)


def _certificate(x, labels, prices: tuple, mode: str, k: int, vm):
    """(dual_bound, gap) from the carried duals, or (None, None) under mesh.

    The mesh path's per-shard price stacks index shard-local clusters; the
    global gather is a follow-up -- every other mode reports the
    certificate (see ``repro.core.objective.dual_certificate``).
    """
    if mode == "mesh" or prices is None:
        return None, None
    from repro.core.objective import dual_certificate
    return dual_certificate(x, labels, _cluster_prices(prices, mode), k,
                            valid_mask=vm)


def _mesh_pad_rows(spec: AnticlusterSpec, shape: tuple[int, ...],
                   has_mask: bool) -> int:
    """Zero rows the mesh path auto-pads for ``n % n_shards != 0``.

    The padding rides the per-call ``valid_mask`` path (padding rows are
    masked out and the result is sliced back to ``n``), so it is only
    available when the caller brings no mask of their own -- with a user
    mask present the explicit divisibility error in ``_route`` stands (the
    two mask sources cannot compose).
    """
    if spec.mesh is None or len(shape) != 2 or has_mask:
        return 0
    return (-shape[0]) % max(_mesh_shards(spec), 1)


def anticluster(x, spec: AnticlusterSpec | None = None,
                **overrides) -> AnticlusterResult:
    """Partition ``x`` into ``spec.k`` anticlusters per the spec.

    The one-shot form of the session API: equivalent to
    ``AnticlusterEngine(spec).partition(x)[0]`` (bit-for-bit -- both sides
    run the same ``_route``/``_call_core`` dispatch with cold prices) but
    calling the module-level jitted cores directly, so repeated one-shot
    calls share the global compile cache instead of building per-session
    executables.  Use :class:`AnticlusterEngine` when you call repeatedly on
    same-shaped data and want warm-started prices + donated state buffers.

    Args:
      x: (n, d) features, or a stacked (G, M, D) batch of padded subproblems
        (pair with ``spec.valid_mask``; the stacked rank requires a flat
        plan -- hierarchy inside each group is not supported).
      spec: an :class:`AnticlusterSpec`; keyword ``overrides`` are applied on
        top (or used alone: ``anticluster(x, k=10)``).

    Returns:
      :class:`AnticlusterResult` with labels, the resolved plan, per-cluster
      sizes and diversity statistics.
    """
    spec = _resolve_spec(spec, overrides)

    x = jnp.asarray(x)
    if x.ndim not in (2, 3):
        raise ValueError(f"x must be (n, d) or (G, M, D), got {x.shape}")
    if spec.kplus_moments > 1:
        if x.ndim != 2 or spec.valid_mask is not None:
            raise NotImplementedError(
                "kplus_moments needs flat unmasked (n, d) input (the moment "
                "statistics are computed over the row axis)")
        x = jnp.asarray(kplus_augment(np.asarray(x), spec.kplus_moments))
    x = x.astype(spec.dtype)

    cats, n_categories, codes, n_codes = _resolve_constraints(spec)
    vm = None if spec.valid_mask is None else jnp.asarray(
        spec.valid_mask, jnp.bool_)
    get_solver(spec.solver)  # fail fast with the registered-name list

    n_rows = x.shape[0]
    pad = _mesh_pad_rows(spec, tuple(x.shape), vm is not None)
    x_solve, vm_solve, cats_solve, codes_solve = x, vm, cats, codes
    if pad:
        x_solve = jnp.concatenate(
            [x, jnp.zeros((pad, x.shape[1]), x.dtype)])
        vm_solve = jnp.concatenate([jnp.ones((n_rows,), jnp.bool_),
                                    jnp.zeros((pad,), jnp.bool_)])
        if cats is not None:  # padding rows draw an arbitrary stratum
            cats_solve = jnp.concatenate(
                [cats, jnp.zeros((pad,), jnp.int32)])
        if codes is not None:
            codes_solve = jnp.concatenate(
                [codes, jnp.zeros((pad, codes.shape[-1]), jnp.int32)])
    mode, plan, solver, chunk = _route(spec, tuple(x_solve.shape),
                                       cats is not None,
                                       vm_solve is not None)

    want_state = spec.stats and mode != "mesh"
    with obs.span("anticluster", shape=tuple(x_solve.shape), mode=mode,
                  solver=solver, k=spec.k):
        out = _call_core(x_solve, spec, mode, plan, solver, chunk,
                         cats_solve, n_categories, vm_solve,
                         codes=codes_solve, n_codes=n_codes,
                         return_state=want_state)
        labels, st = out if want_state else (out, None)
        # Finish the label computation before dispatching the statistics
        # ops: host-callback solvers (e.g. "scipy") deadlock on CPU if new
        # work is enqueued while their callback computation is still in
        # flight.  (examples/scipy_deadlock_repro.py demonstrates the hang
        # this guard prevents;
        # tests/test_anticluster.py::test_scipy_solver_stats_no_deadlock
        # pins it.)
        labels = jax.block_until_ready(labels)
    if mode == "mesh":
        n_shards = _mesh_shards(spec)
        plan = ((n_shards,) + plan) if n_shards > 1 else plan
    if pad:
        labels = labels[:n_rows]
    sizes, sd, rng = _result_stats(x, labels, spec.k, vm,
                                   diversity=spec.stats)
    bound, gap = (None, None) if st is None else _certificate(
        x, labels, st["prices"], mode, spec.k, vm)
    return AnticlusterResult(
        labels=labels, cluster_sizes=sizes, diversity_sd=sd,
        diversity_range=rng, k=spec.k, plan=plan, solver=solver,
        variant=spec.variant, dual_bound=bound, gap=gap)


class AnticlusterEngine:
    """Device-resident, warm-startable session API for repeated solves.

    One engine per repeated workload (a training run's per-epoch mini-batch
    partitions, a CV harness, a serving lane).  The engine builds ONE
    jit-compiled executable per input signature ``(shape, dtype)`` --
    verified by :attr:`compile_count` staying at 1 across same-shape epochs
    -- with the incoming :class:`ABAState` buffers donated (on backends that
    support donation the old state's memory is reused in place), and keeps
    the result *statistics* out of the compiled path (they are host-level
    conveniences, skippable via ``spec.stats=False``).

    ``partition(x)`` is the cold start: it runs with a zeroed state and is
    bit-for-bit identical to ``anticluster(x, spec)``.  ``repartition(x,
    state)`` threads the carried state through the cores: every batch LAP at
    every hierarchy level warm-starts its epsilon-scaling schedule from the
    previous run's final prices, which is where the paper's repeated
    workloads (Section 1) recover their throughput -- the assignment stays
    eps-optimal (warm prices change round counts, not the optimality
    guarantee), and the objective stays within the auction's usual tolerance
    of the cold solve.

    A spec with a ``mesh`` makes the session *distributed*: the engine
    compiles ONE ``shard_map``-based executable (per input signature) whose
    state is a :class:`ShardedABAState` -- per-shard, per-level price stacks
    laid out with ``jax.sharding.NamedSharding`` over the spec's data axes
    (see :meth:`state_shardings`) -- so warm-started repartitioning runs
    collective-free across the mesh with zero retraces and zero resharding,
    and a zeroed sharded state reproduces the one-shot mesh path bit for
    bit.  Everything the shard-local core supports composes: streaming
    (``chunk_size``), categories, valid_mask (flat per-shard plans).

    Not supported here (use the one-shot :func:`anticluster`):
    ``spec.kplus_moments > 1`` (host-side feature augmentation),
    ``spec.batched=False`` (legacy benchmarking path).
    """

    _donation_advisory_silenced = False

    def __init__(self, spec: AnticlusterSpec | None = None, **overrides):
        # Engines always request state-buffer donation; backends that cannot
        # honor it (CPU) emit an advisory per executable.  Install the filter
        # once, process-wide -- a per-call warnings.catch_warnings() would
        # mutate global filter state on every repartition and race under
        # threaded serving.
        if not AnticlusterEngine._donation_advisory_silenced:
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            AnticlusterEngine._donation_advisory_silenced = True
        spec = _resolve_spec(spec, overrides)
        if spec.mesh is not None:
            _mesh_shards(spec)  # fail fast on bad data_axes / mesh
        if spec.kplus_moments > 1:
            raise NotImplementedError(
                "kplus_moments augmentation is host-side; use the one-shot "
                "anticluster()")
        if not spec.batched:
            raise NotImplementedError(
                "the engine requires the batched level engine "
                "(spec.batched=True)")
        get_solver(spec.solver)  # fail fast
        self.spec = spec
        (self._cats, self._n_categories,
         self._codes, self._n_codes) = _resolve_constraints(spec)
        self._vm = (None if spec.valid_mask is None
                    else jnp.asarray(spec.valid_mask, jnp.bool_))
        self._fns: dict = {}
        self._routes: dict = {}  # shape -> (mode, plan, solver, chunk)
        self._trace_count = 0
        #: host-side (NumPy) copy of the last solve's solver telemetry
        #: pytree; stays None unless ``spec.telemetry`` is set (see
        #: :class:`AnticlusterSpec`).
        self.last_telemetry = None

    @property
    def compile_count(self) -> int:
        """Number of executable traces built so far (1 per input signature).

        Incremented from inside the traced function, so it counts actual
        (re)traces -- the compile-exactly-once contract across same-shape
        epochs is ``engine.compile_count == 1``.
        """
        return self._trace_count

    def _routed(self, shape: tuple[int, ...], has_vm: bool | None = None):
        # memoized: repartition is the per-epoch hot path and the route
        # (incl. resolve_plan's factorization search) is static per shape.
        # ``has_vm`` defaults to the spec's static mask; a per-call mask
        # (see ``repartition``) routes with has_vm=True for the same shape.
        if has_vm is None:
            has_vm = self._vm is not None
        key = (shape, has_vm)
        routed = self._routes.get(key)
        if routed is None:
            routed = _route(self.spec, shape, self._cats is not None,
                            has_vm)
            self._routes[key] = routed
        return routed

    def _solve_shape(self, shape: tuple[int, ...]):
        """``(padded_shape, pad)`` the executables actually run on.

        Mesh sessions auto-pad ``n % n_shards != 0`` inputs with ``pad``
        masked zero rows (see ``_mesh_pad_rows``); every state/shape query
        and ``repartition`` itself agree on this padded geometry, and
        results are sliced back to the caller's ``n``.  ``pad == 0``
        everywhere else.
        """
        shape = tuple(shape)
        pad = _mesh_pad_rows(self.spec, shape, self._vm is not None)
        if pad:
            return (shape[0] + pad, shape[1]), pad
        return shape, 0

    def price_shapes(self, shape) -> tuple[tuple[int, ...], ...]:
        """Per-level price shapes of the state carried for input ``shape``.

        Mesh specs carry per-shard stacks: each level's shape gains a
        leading ``n_shards`` axis (see :class:`ShardedABAState`).
        """
        shape, pad = self._solve_shape(tuple(shape))
        mode, plan, _solver, _chunk = self._routed(
            shape, True if pad else None)
        if mode == "mesh":
            from repro.core.sharded import sharded_price_shapes
            return sharded_price_shapes(plan, _mesh_shards(self.spec))
        if mode == "stacked":
            return ((shape[0], self.spec.k),)
        if mode == "hier":
            return plan_price_shapes(plan)
        return ((1, self.spec.k),)

    def state_shardings(self, x_or_shape):
        """NamedShardings matching the session state for input ``shape``.

        ``None`` for meshless specs (single-device state).  For mesh specs,
        a :class:`ShardedABAState`-shaped tree of
        ``jax.sharding.NamedSharding`` leaves sharding every leading axis
        over the spec's data axes -- the layout ``init_state`` places its
        zeros with, ``repartition`` keeps, and a checkpoint restore should
        ``device_put`` with (``repro.train.checkpoint.restore_engine_state``
        does).
        """
        shape = (tuple(x_or_shape) if isinstance(x_or_shape, (tuple, list))
                 else tuple(jnp.shape(x_or_shape)))
        shape, pad = self._solve_shape(shape)
        if self._routed(shape, True if pad else None)[0] != "mesh":
            return None
        axes = resolve_data_axes(self.spec.mesh, self.spec.data_axes)
        # eval_shape: leaf ranks without materializing a throwaway state
        like = jax.eval_shape(lambda: self._cold_state(shape))
        return shard_leading(self.spec.mesh, axes, like)

    def _cold_state(self, shape):
        """Host-side zeroed state pytree for ``shape`` (no placement)."""
        shape, pad = self._solve_shape(shape)
        mode, _plan, _solver, _chunk = self._routed(
            shape, True if pad else None)
        prices = tuple(jnp.zeros(s, jnp.float32)
                       for s in self.price_shapes(shape))
        if mode == "mesh":
            n, d = shape
            n_shards = _mesh_shards(self.spec)
            return ShardedABAState(
                prices, jnp.zeros((n_shards, d), jnp.float32),
                jnp.zeros((n_shards,), jnp.float32),
                jnp.full((n,), -1, jnp.int32))
        if mode == "stacked":
            G, M, D = shape
            return ABAState(prices, jnp.zeros((G, D), jnp.float32),
                            jnp.zeros((G,), jnp.float32),
                            jnp.full((G, M), -1, jnp.int32))
        n, d = shape
        return ABAState(prices, jnp.zeros((d,), jnp.float32),
                        jnp.zeros((), jnp.float32),
                        jnp.full((n,), -1, jnp.int32))

    def init_state(self, x_or_shape) -> "ABAState | ShardedABAState":
        """A zeroed (cold-start) state for ``x`` / its shape.

        :class:`ABAState` for meshless specs; :class:`ShardedABAState`
        (placed with :meth:`state_shardings`) for mesh specs.
        """
        shape = (tuple(x_or_shape) if isinstance(x_or_shape, (tuple, list))
                 else tuple(jnp.shape(x_or_shape)))
        state = self._cold_state(shape)
        shardings = self.state_shardings(shape)
        return state if shardings is None else jax.device_put(state,
                                                              shardings)

    def partition(self, x, *,
                  valid_mask=None) -> tuple[AnticlusterResult, ABAState]:
        """Cold solve: ``repartition`` from a zeroed state (bit-identical to
        ``anticluster(x, spec)``); compiles on first use per shape."""
        return self.repartition(x, self.init_state(jnp.shape(x)),
                                valid_mask=valid_mask)

    def repartition(self, x, state, *,
                    valid_mask=None) -> tuple[AnticlusterResult, Any]:
        """Warm solve: same-shape re-partition carrying ``state``'s prices.

        The state is *consumed* (its buffers are donated to the compiled
        call); use the returned state for the next epoch.  A zeroed state
        (``init_state``) reproduces ``partition`` bit-for-bit.  Mesh specs
        take and return a :class:`ShardedABAState` (per-shard layout kept
        end to end); meshless specs an :class:`ABAState`.

        ``valid_mask`` marks padding rows *per call* (bool, the labels'
        shape): unlike ``spec.valid_mask`` (one static mask baked into the
        session) it is a runtime argument of the same compiled executable,
        so one engine can serve differently-padded same-shape inputs with
        zero retraces -- the serving tier's row-bucket admission
        (`repro.serve`) leans on this.  Masked rows never influence real
        rows and draw arbitrary labels in [0, k); mutually exclusive with
        ``spec.valid_mask``.
        """
        return self._dispatch(x, state, valid_mask).wait()

    def overlap_capable(self, x_or_shape) -> bool:
        """Whether :meth:`dispatch_repartition` can overlap for this input.

        False iff the route's resolved solver executes on the host from
        inside the trace (``Solver.host_callback`` -- e.g. ``"scipy"`` via
        ``jax.pure_callback``): such a solve occupies the host thread while
        in flight, so an async dispatch buys nothing and risks the known
        host-callback deadlock the stats guard exists for.
        """
        shape = (tuple(x_or_shape) if isinstance(x_or_shape, (tuple, list))
                 else tuple(jnp.shape(x_or_shape)))
        shape, pad = self._solve_shape(shape)
        _mode, _plan, solver, _chunk = self._routed(
            shape, True if pad else None)
        return not get_solver(solver).host_callback

    def dispatch_repartition(self, x, state, *,
                             valid_mask=None) -> "PendingRepartition":
        """Non-blocking warm repartition: enqueue the solve, don't sync.

        Runs exactly :meth:`repartition`'s validation and compiled call but
        returns immediately after the async dispatch (JAX queues the
        executable; the host thread never touches ``block_until_ready``).
        The returned :class:`PendingRepartition` finishes the epoch on
        ``wait()`` -- ``dispatch_repartition(x, state).wait()`` is
        bit-for-bit identical to ``repartition(x, state)``, stats included.

        ``state`` is consumed at dispatch time (buffers donated), so thread
        states linearly: never reuse a state an in-flight call took.

        Raises ``RuntimeError`` when :meth:`overlap_capable` is False (a
        host-callback solver such as ``"scipy"`` -- dispatch would occupy
        the host thread anyway); callers wanting a fallback should check
        ``overlap_capable`` and call :meth:`repartition` instead, as
        ``repro.train.pipeline.ABAPipeline`` does.
        """
        shape = tuple(jnp.shape(x))
        if not self.overlap_capable(shape):
            _mode, _plan, solver, _chunk = self._routed(
                self._solve_shape(shape)[0])
            raise RuntimeError(
                f"solver {solver!r} runs via a host callback and cannot be "
                "dispatched asynchronously (the solve occupies the host "
                "thread -- no overlap is possible); check "
                "engine.overlap_capable(x) and use the synchronous "
                "repartition() instead")
        return self._dispatch(x, state, valid_mask)

    def _dispatch(self, x, state, valid_mask) -> "PendingRepartition":
        """Validate, resolve the route and enqueue the compiled solve.

        Shared tail of :meth:`repartition` (which ``wait()``s inline) and
        :meth:`dispatch_repartition` (which hands the pending handle out):
        everything up to -- but excluding -- the first sync lives here.
        """
        spec = self.spec
        x = jnp.asarray(x).astype(spec.dtype)
        shape = tuple(x.shape)
        vm = self._vm
        per_call_mask = valid_mask is not None
        if per_call_mask:
            if self._vm is not None:
                raise ValueError(
                    "spec.valid_mask and a per-call valid_mask are mutually "
                    "exclusive; build the engine without spec.valid_mask to "
                    "pass masks per call")
            vm = jnp.asarray(valid_mask, jnp.bool_)
            if tuple(vm.shape) != shape[:-1]:
                raise ValueError(
                    f"valid_mask shape {tuple(vm.shape)} does not match the "
                    f"label shape {shape[:-1]} of input {shape}")
        n_rows = shape[0]
        pad = 0
        if not per_call_mask:
            solve_shape, pad = self._solve_shape(shape)
            if pad:
                # mesh auto-pad: masked zero rows make n divisible by the
                # shard count; the pad mask rides the per-call-mask
                # executable, so it composes with warm state like any mask
                x = jnp.concatenate(
                    [x, jnp.zeros((pad, shape[1]), x.dtype)])
                vm = jnp.concatenate([jnp.ones((n_rows,), jnp.bool_),
                                      jnp.zeros((pad,), jnp.bool_)])
                shape = solve_shape
                per_call_mask = True
        mode, plan, solver, _chunk = self._routed(shape, vm is not None)
        state_cls = ShardedABAState if mode == "mesh" else ABAState
        if not isinstance(state, state_cls):
            raise TypeError(
                f"a {'mesh' if mode == 'mesh' else 'single-device'} engine "
                f"carries {state_cls.__name__}, got "
                f"{type(state).__name__} (build states with "
                "engine.init_state / previous repartition calls)")
        expected = self.price_shapes(shape)
        got = tuple(tuple(p.shape) for p in state.prices)
        if got != expected:
            raise ValueError(
                f"state prices {got} do not match the {expected} this "
                f"engine carries for input shape {shape} (state from a "
                "different shape/plan?)")
        key = (shape, jnp.dtype(spec.dtype).name, per_call_mask)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._build(shape, per_call_mask=per_call_mask)
            self._fns[key] = fn
        span = None
        if obs.enabled():
            # async span: dispatch and wait() may happen on different
            # threads / stack frames (the pipeline's overlapped epochs)
            span = obs.begin("engine/repartition", shape=shape, mode=mode,
                             solver=solver, k=spec.k,
                             telemetry=spec.telemetry)
            if mode == "stream":
                obs.event("stream/plan", shape=shape, chunk=_chunk)
        args = (x, tuple(state.prices)) + ((vm,) if per_call_mask else ())
        if spec.telemetry:
            labels, prices, msum, mcnt, tele = fn(*args)
        else:
            labels, prices, msum, mcnt = fn(*args)
            tele = None
        return PendingRepartition(self, x, vm, labels, prices, msum, mcnt,
                                  mode, plan, solver, pad, n_rows, state_cls,
                                  tele=tele, span=span)

    def update(self, x, state, *, added=None,
               removed=None) -> tuple[AnticlusterResult, Any, ABAState]:
        """Absorb a delta into a live partition without a full re-solve.

        ``x``/``state`` are the current (n, d) rows and the
        :class:`ABAState` from the ``partition``/``repartition``/``update``
        call that produced them.  ``removed`` names departing rows of ``x``
        (int indices or an (n,) bool mask); ``added`` is an (m, d) block of
        arriving rows.  Returns ``(result, new_x, new_state)`` where
        ``new_x = concat(x[kept], added)`` is the post-delta row order the
        labels/state refer to -- feed the pair straight into the next
        ``update``/``repartition``.

        Small deltas take the *incremental* path (``result.updated`` is
        True): kept rows keep their labels, departures free capacity and
        down-date the carried centrality moments, and arrivals are assigned
        by a restricted auction over the open cluster slots with every
        other dual price frozen (see :mod:`repro.incremental`).  The delta
        path falls back -- loudly, with a ``RuntimeWarning`` -- to a full
        warm ``repartition`` (``result.updated`` False) when the delta
        exceeds ``spec.update_threshold * n_new`` or balance cannot be
        restored locally; the fallback is bit-for-bit identical to calling
        ``repartition`` on the post-delta rows with the carried prices.
        A zero delta is exactly ``repartition(x, state)``.

        Flat / streamed / hierarchical category-free sessions only; mesh,
        stacked, categorical, and masked sessions raise
        ``NotImplementedError`` (repartition instead).
        """
        from repro import incremental as _incremental
        return _incremental.engine_update(self, x, state, added=added,
                                          removed=removed)

    def _build(self, shape: tuple[int, ...], per_call_mask: bool = False):
        """One shape-keyed executable: solve + state refresh, donated state.

        Mesh specs compile the whole thing -- ``shard_map`` execution plus
        the per-shard price refresh -- into this one jitted callable too, so
        distributed repartitioning retraces exactly as often as the local
        path: once per input signature.  With ``per_call_mask`` the valid
        mask is a runtime argument of the executable (one trace covers every
        padding pattern of the shape) instead of a baked-in constant.
        """
        spec = self.spec
        mode, plan, solver, chunk = self._routed(
            shape, True if per_call_mask else None)
        cats, ncats = self._cats, self._n_categories
        codes, ncodes = self._codes, self._n_codes
        if (cats is not None and len(shape) == 2
                and cats.shape[0] < shape[0]):
            # mesh auto-pad: padding rows draw an arbitrary stratum (they
            # are masked out, so quotas over real rows are unaffected)
            pad_n = shape[0] - cats.shape[0]
            cats = jnp.concatenate([cats, jnp.zeros((pad_n,), jnp.int32)])
            if codes is not None:
                codes = jnp.concatenate(
                    [codes, jnp.zeros((pad_n, codes.shape[-1]), jnp.int32)])

        def body(x, prices, vm):
            self._trace_count += 1  # python side effect: runs once per trace
            labels, st = _call_core(x, spec, mode, plan, solver, chunk,
                                    cats, ncats, vm, codes=codes,
                                    n_codes=ncodes, prices=prices,
                                    return_state=True,
                                    telemetry=spec.telemetry)
            # solver telemetry rides the output pytree only when the spec
            # opts in -- the default executable is byte-identical to the
            # pre-telemetry one (the engine compile_count pins rely on it)
            tele = st.pop("telemetry", None) if spec.telemetry else None
            # re-center the dual prices per group (the auction is invariant
            # to a uniform shift) so carried state stays bounded over epochs
            new_prices = tuple(p - jnp.max(p, axis=-1, keepdims=True)
                               for p in st["prices"])
            if mode == "mesh":
                # per-shard moments come straight from the sharded state
                out = (labels, new_prices, st["moment_sum"],
                       st["moment_count"])
                return out + (tele,) if spec.telemetry else out
            mu = st["mu"]
            if mode == "stacked":
                cnt = (jnp.full((shape[0],), float(shape[1]), jnp.float32)
                       if vm is None else jnp.sum(vm, axis=1, dtype=jnp.float32))
            else:
                cnt = (jnp.asarray(float(shape[0]), jnp.float32)
                       if vm is None else jnp.sum(vm, dtype=jnp.float32))
            out = (labels, new_prices, mu * cnt[..., None], cnt)
            return out + (tele,) if spec.telemetry else out

        if per_call_mask:
            return jax.jit(lambda x, prices, vm: body(x, prices, vm),
                           donate_argnums=(1,))
        static_vm = self._vm
        return jax.jit(lambda x, prices: body(x, prices, static_vm),
                       donate_argnums=(1,))


class PendingRepartition:
    """An in-flight (asynchronously dispatched) engine repartition.

    Produced by :meth:`AnticlusterEngine.dispatch_repartition`: the compiled
    solve is already enqueued on the device; the arrays held here are JAX's
    async futures.  ``wait()`` performs the one deliberate sync (the same
    ``block_until_ready`` guard ``repartition`` uses before its host-level
    statistics) and finishes the result exactly as the synchronous path
    would -- ``dispatch(...).wait()`` is bit-for-bit ``repartition(...)``.

    ``wait()`` is idempotent (the finished pair is cached).  ``ready()``
    polls completion without blocking, for callers that want to interleave
    more host work while the solve drains.
    """

    def __init__(self, engine, x, vm, labels, prices, msum, mcnt,
                 mode, plan, solver, pad, n_rows, state_cls,
                 tele=None, span=None):
        self._engine = engine
        self._x, self._vm = x, vm
        self._labels, self._prices = labels, prices
        self._msum, self._mcnt = msum, mcnt
        self._mode, self._plan, self._solver = mode, plan, solver
        self._pad, self._n_rows = pad, n_rows
        self._state_cls = state_cls
        self._tele = tele
        self._span = span
        self._done: tuple | None = None

    def ready(self) -> bool:
        """True iff the dispatched solve has finished (non-blocking)."""
        if self._done is not None:
            return True
        try:
            return all(a.is_ready() for a in jax.tree_util.tree_leaves(
                (self._labels, self._prices)))
        except AttributeError:  # backend arrays without is_ready()
            return True

    def wait(self) -> tuple[AnticlusterResult, Any]:
        """Sync, compute stats (per spec) and return ``(result, state)``."""
        if self._done is not None:
            return self._done
        engine, spec = self._engine, self._engine.spec
        x, vm = self._x, self._vm
        mode, plan, solver = self._mode, self._plan, self._solver
        pad, n_rows = self._pad, self._n_rows
        # Finish labels before dispatching the (host-level) statistics ops:
        # host-callback solvers deadlock otherwise (see anticluster()).
        labels = jax.block_until_ready(self._labels)
        prices, msum, mcnt = self._prices, self._msum, self._mcnt
        if self._tele is not None:
            # hold the solver telemetry on the host (NumPy) so the session
            # can inspect it after the donated device state is gone
            engine.last_telemetry = jax.tree_util.tree_map(
                np.asarray, self._tele)
        if mode == "mesh":
            n_shards = _mesh_shards(spec)
            plan = ((n_shards,) + plan) if n_shards > 1 else plan
        # padding rows are masked in vm, so the stats match the unpadded run
        sizes, sd, rng = _result_stats(x, labels, spec.k, vm,
                                       diversity=spec.stats)
        bound, gap = (None, None)
        if spec.stats:
            bound, gap = _certificate(x, labels, prices, mode, spec.k, vm)
        result = AnticlusterResult(
            labels=labels[:n_rows] if pad else labels, cluster_sizes=sizes,
            diversity_sd=sd, diversity_range=rng, k=spec.k, plan=plan,
            solver=solver, variant=spec.variant, dual_bound=bound, gap=gap)
        # the state keeps the padded geometry (labels' length keys the shape)
        state = self._state_cls(prices=prices, moment_sum=msum,
                                moment_count=mcnt, prev_labels=labels)
        if self._span is not None:
            summary = obs.summarize_auction_telemetry(
                engine.last_telemetry if self._tele is not None else None)
            if summary is not None:
                self._span.set(rounds_total=summary["rounds_total"],
                               warm_fraction=summary.get("warm_fraction"))
                trace = obs.active()
                if trace is not None:
                    for phase, r in enumerate(summary["rounds_per_phase"]):
                        trace.event("solver/phase", phase=phase,
                                    rounds=int(r))
            self._span.finish(gap=gap)
        self._done = (result, state)
        self._x = self._labels = self._prices = None  # free the refs
        self._msum = self._mcnt = None
        self._tele = self._span = None
        return self._done
