"""The spec-driven front door for Euclidean anticlustering.

One entry point replaces the six legacy ones (``aba``, ``aba_batched``,
``hierarchical_aba``, ``aba_auto``, ``sharded_aba``, ``aba_reference``):

    from repro.anticluster import AnticlusterSpec, anticluster

    res = anticluster(x, AnticlusterSpec(k=500))          # flat or auto-plan
    res = anticluster(x, k=500, plan=(10, 50))            # explicit hierarchy
    res = anticluster(x, k=5, categories=y)               # stratified (4.3)
    res = anticluster(x, k=512, mesh=mesh)                # shard_map across mesh
    res.labels, res.plan, res.cluster_sizes, res.balanced # result pytree

``anticluster`` routes flat -> streaming -> hierarchical -> sharded
execution from the spec alone; every regime runs on the ONE rank-polymorphic
masked core (``repro.core.aba.aba_core``) so there is exactly one
implementation of the centrality sort / padding / Algorithm-1 scan.  At
million-object scale (``chunk_size="auto"`` or an explicit int) the flat
level runs through the chunked matrix-free twin ``repro.core.aba.aba_stream``
(same per-batch step, O(chunk*d + k*d) working set, bit-identical labels
when ``chunk_size >= n``).  The LAP backend is looked up
in the solver registry (``register_solver`` / ``get_solver``), so new
backends are a registry entry, not a seventh entry point.

``anticluster`` itself is a host-level convenience (it builds the result
statistics eagerly); inside ``jit``/``scan``/``shard_map`` call the cores
directly (``aba_core`` / ``hierarchical_core`` / ``sharded_core``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aba import aba_core, aba_stream
from repro.core.assignment import (AuctionConfig, available_solvers,
                                   get_solver, register_solver)
from repro.core.hierarchical import default_plan, hierarchical_core
from repro.core.kplus import kplus_augment

__all__ = [
    "AnticlusterSpec", "AnticlusterResult", "anticluster",
    "register_solver", "get_solver", "available_solvers",
]

# Streaming auto-selection thresholds: below _AUTO_STREAM_MIN rows the dense
# core's one-shot gather is cheap and ``chunk_size="auto"`` stays flat; at or
# above it the streaming core engages with ~_AUTO_CHUNK_ROWS rows per chunk
# (rounded to a multiple of k inside ``aba_stream``), keeping the working
# set O(chunk*d + k*d) regardless of n.
_AUTO_STREAM_MIN = 1 << 16   # 65536 rows
_AUTO_CHUNK_ROWS = 1 << 13   # 8192 rows per chunk


@dataclasses.dataclass(frozen=True, eq=False)
class AnticlusterSpec:
    """Frozen configuration for :func:`anticluster`.

    Attributes:
      k: number of anticlusters (required).
      variant: "auto" | "base" | "interleave" (paper Section 4.2; "auto"
        interleaves when anticlusters are small, n/k <= 8).
      categories: optional (n,) int category labels -- Section 4.3 exact
        stratification.  Composes with hierarchy: every level stratifies
        within its groups, and the global constraint (5) still holds exactly
        (ceil/floor compose across levels, see ``repro.core.hierarchical``).
      n_categories: static category count; 0 infers it from ``categories``.
      solver: LAP backend name in the solver registry ("auction",
        "auction_fused", "greedy", "scipy", or anything you
        ``register_solver``-ed).
      auction_config: epsilon-scaling schedule for the auction backends.
      plan: hierarchy plan (Section 4.4).  ``"auto"`` factorizes k with
        ``default_plan`` (every factor <= ``max_k``); a tuple is used as-is
        (must multiply to k); ``None`` forces the flat single-level path.
      chunk_size: streaming execution (million-scale path).  ``None`` keeps
        the dense one-shot core; an int streams the centrality-sorted object
        list through ``repro.core.aba.aba_stream`` in chunks of that many
        rows (peak live memory O(chunk_size*d + k*d) beyond the input);
        ``"auto"`` streams only at scale (n >= 65536 rows, ~8192-row chunks)
        and additionally upgrades the default "auction" solver to
        "auction_fused" so each batch LAP is matrix-free (the (k, k) value
        matrix is never built -- the paper's Tables 8/10 operating range).
        Applies to the flat path, the first (full-data) hierarchical level,
        and each shard's local solve under ``mesh``.  Streaming needs flat
        category-free unmasked input: an explicit int raises otherwise,
        ``"auto"`` quietly stays dense.  With ``chunk_size >= n`` labels are
        bit-for-bit identical to the dense path.
      max_k: largest admissible LAP size for the auto plan.
      mesh: optional ``jax.sharding.Mesh`` -- routes through ``shard_map``
        (the data sharding becomes the first hierarchy level); k must be
        divisible by the shard count of ``data_axes``.
      data_axes: mesh axes that shard the data.
      valid_mask: optional bool mask marking padding rows (shape of labels);
        masked rows get arbitrary labels in [0, k).
      kplus_moments: >= 2 augments features with standardized centered
        moments (k-plus, Section 3.3) before clustering; flat unmasked
        (n, d) input only.
      dtype: feature dtype fed to the core (the core computes in float32).
      batched: False switches hierarchical levels to the legacy vmap of
        per-group solves (identical labels; exists for benchmarking).
      stats: False skips the diversity statistics (sd/range report 0) so
        timed benchmark windows measure only the solve + cluster sizes.
    """

    k: int
    variant: str = "auto"
    categories: Any = None
    n_categories: int = 0
    solver: str = "auction"
    auction_config: AuctionConfig = AuctionConfig()
    plan: Any = "auto"
    chunk_size: Any = None
    max_k: int = 512
    mesh: Any = None
    data_axes: tuple[str, ...] = ("pod", "data")
    valid_mask: Any = None
    kplus_moments: int = 1
    dtype: Any = jnp.float32
    batched: bool = True
    stats: bool = True

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k={self.k} must be >= 1")
        if isinstance(self.plan, tuple) and math.prod(self.plan) != self.k:
            raise ValueError(
                f"prod(plan)={math.prod(self.plan)} != k={self.k}")
        if self.plan is not None and not isinstance(self.plan, tuple) \
                and self.plan != "auto":
            raise ValueError(f'plan must be "auto", a tuple, or None; '
                             f"got {self.plan!r}")
        if self.chunk_size is not None and self.chunk_size != "auto" and \
                (not isinstance(self.chunk_size, int)
                 or self.chunk_size < 1):
            raise ValueError(f'chunk_size must be None, "auto", or a '
                             f"positive int; got {self.chunk_size!r}")

    def replace(self, **overrides) -> "AnticlusterSpec":
        return dataclasses.replace(self, **overrides)

    def resolve_plan(self) -> tuple[int, ...]:
        """The concrete per-device hierarchy plan this spec dispatches to."""
        if self.plan is None:
            return (self.k,)
        if isinstance(self.plan, tuple):
            return self.plan
        k = self.k
        if self.mesh is not None:
            axes = [a for a in self.data_axes if a in self.mesh.axis_names]
            n_shards = math.prod(self.mesh.shape[a] for a in axes)
            if k % n_shards:
                raise ValueError(
                    f"k={k} must be divisible by shard count {n_shards}")
            k = k // n_shards
        return default_plan(k, max_k=self.max_k)

    def resolve_chunk(self, n: int, k: int) -> int | None:
        """Concrete per-level chunk size for ``n`` rows, or None (dense).

        ``k`` is the level's anticluster count (the chunk is rounded to a
        multiple of it inside ``aba_stream``); "auto" engages only when the
        level is large enough for chunking to pay for itself.
        """
        if self.chunk_size is None:
            return None
        if self.chunk_size == "auto":
            if n < _AUTO_STREAM_MIN:
                return None
            return max(k, _AUTO_CHUNK_ROWS)
        return int(self.chunk_size)


@dataclasses.dataclass(frozen=True)
class AnticlusterResult:
    """Labels plus the resolved execution plan and quality statistics.

    A pytree: ``labels`` / ``cluster_sizes`` / ``diversity_sd`` /
    ``diversity_range`` are leaves, the resolved ``plan`` and the spec echoes
    (``k``, ``solver``, ``variant``) are static metadata.  For stacked
    (G, M, D) inputs every field carries the leading group axis.
    """

    labels: jnp.ndarray          # (n,) or (G, M) int32 in [0, k)
    cluster_sizes: jnp.ndarray   # (k,) or (G, k) int32 (valid rows only)
    diversity_sd: jnp.ndarray    # () or (G,) std of per-cluster diversity
    diversity_range: jnp.ndarray  # () or (G,) max - min of the same
    k: int = 1
    plan: tuple[int, ...] = ()
    solver: str = "auction"
    variant: str = "auto"

    @property
    def n_valid(self):
        """Number of non-padding rows (per group for stacked inputs)."""
        return np.asarray(self.cluster_sizes).sum(axis=-1)

    @property
    def balanced(self) -> bool:
        """Constraint (2): all sizes in {floor(n/k), ceil(n/k)} (Prop. 1)."""
        sizes = np.asarray(self.cluster_sizes)
        n = sizes.sum(axis=-1, keepdims=True)
        return bool(np.all(sizes >= n // self.k)
                    and np.all(sizes <= -(-n // self.k)))


jax.tree_util.register_dataclass(
    AnticlusterResult,
    data_fields=["labels", "cluster_sizes", "diversity_sd",
                 "diversity_range"],
    meta_fields=["k", "plan", "solver", "variant"])


def _mesh_shards(spec: "AnticlusterSpec") -> int:
    """Total data-parallel shard count for the spec's mesh (1 if no mesh)."""
    if spec.mesh is None:
        return 1
    axes = [a for a in spec.data_axes if a in spec.mesh.axis_names]
    return math.prod(spec.mesh.shape[a] for a in axes)


def _result_stats(x, labels, k, valid_mask, diversity=True):
    """Masked per-group (sizes, diversity sd, diversity range).

    The masked/grouped generalization of ``repro.core.objective``'s
    ``cluster_sizes`` / ``diversity_stats`` (which stay the flat fast path);
    a drift guard in tests/test_anticluster.py pins the two to each other.
    """
    squeeze = x.ndim == 2
    if squeeze:
        x, labels = x[None], labels[None]
        valid_mask = None if valid_mask is None else valid_mask[None]
    G, M, D = x.shape
    w = (jnp.ones((G, M), jnp.float32) if valid_mask is None
         else valid_mask.astype(jnp.float32))
    seg = labels + k * jnp.arange(G, dtype=labels.dtype)[:, None]
    seg = jnp.where(w > 0, seg, G * k)  # padding rows -> dump segment
    sizes = jax.ops.segment_sum(
        w.reshape(-1), seg.reshape(-1), num_segments=G * k + 1
    )[:G * k].reshape(G, k).astype(jnp.int32)
    if not diversity:
        zero = jnp.zeros((G,), jnp.float32)
        return (sizes[0], zero[0], zero[0]) if squeeze else (sizes, zero,
                                                             zero)
    sums = jax.ops.segment_sum(
        (x * w[..., None]).reshape(-1, D), seg.reshape(-1),
        num_segments=G * k + 1)[:G * k].reshape(G, k, D)
    mu = sums / jnp.maximum(sizes, 1).astype(jnp.float32)[..., None]
    sq = jnp.sum((x - jnp.take_along_axis(
        mu, labels[..., None], axis=1)) ** 2, axis=-1) * w
    div = jax.ops.segment_sum(
        sq.reshape(-1), seg.reshape(-1), num_segments=G * k + 1
    )[:G * k].reshape(G, k)
    sd = jnp.std(div, axis=1)
    rng = jnp.max(div, axis=1) - jnp.min(div, axis=1)
    if squeeze:
        return sizes[0], sd[0], rng[0]
    return sizes, sd, rng


def anticluster(x, spec: AnticlusterSpec | None = None,
                **overrides) -> AnticlusterResult:
    """Partition ``x`` into ``spec.k`` anticlusters per the spec.

    Args:
      x: (n, d) features, or a stacked (G, M, D) batch of padded subproblems
        (pair with ``spec.valid_mask``; the stacked rank requires a flat
        plan -- hierarchy inside each group is not supported).
      spec: an :class:`AnticlusterSpec`; keyword ``overrides`` are applied on
        top (or used alone: ``anticluster(x, k=10)``).

    Returns:
      :class:`AnticlusterResult` with labels, the resolved plan, per-cluster
      sizes and diversity statistics.
    """
    if spec is None:
        spec = AnticlusterSpec(**overrides)
    elif overrides:
        spec = spec.replace(**overrides)

    x = jnp.asarray(x)
    if x.ndim not in (2, 3):
        raise ValueError(f"x must be (n, d) or (G, M, D), got {x.shape}")
    if spec.kplus_moments > 1:
        if x.ndim != 2 or spec.valid_mask is not None:
            raise NotImplementedError(
                "kplus_moments needs flat unmasked (n, d) input (the moment "
                "statistics are computed over the row axis)")
        x = jnp.asarray(kplus_augment(np.asarray(x), spec.kplus_moments))
    x = x.astype(spec.dtype)

    cats = spec.categories
    n_categories = spec.n_categories
    if cats is not None:
        cats = jnp.asarray(cats, jnp.int32)
        if n_categories <= 0:
            n_categories = int(np.asarray(cats).max()) + 1
    vm = None if spec.valid_mask is None else jnp.asarray(
        spec.valid_mask, jnp.bool_)
    get_solver(spec.solver)  # fail fast with the registered-name list
    plan = spec.resolve_plan()

    # --- streaming route selection (million-scale path) --------------------
    streamable = x.ndim == 2 and cats is None and vm is None
    if spec.chunk_size is not None and not streamable \
            and spec.chunk_size != "auto":
        raise NotImplementedError(
            "chunk_size streaming needs flat (n, d) input without "
            'categories or valid_mask; chunk_size="auto" falls back to the '
            "dense core for those")

    def chunk_for(n_level: int, k_level: int) -> int | None:
        return spec.resolve_chunk(n_level, k_level) if streamable else None

    solver = spec.solver
    if spec.chunk_size == "auto" and solver == "auction" and streamable:
        n_level = x.shape[0] // max(_mesh_shards(spec), 1)
        if chunk_for(n_level, plan[0]) is not None:
            # at scale the matrix-free factored auction is the default engine
            solver = "auction_fused"
    kw = dict(variant=spec.variant, solver=solver,
              auction_config=spec.auction_config)

    if spec.mesh is not None:
        from repro.core.sharded import sharded_core
        if x.ndim != 2 or cats is not None or vm is not None:
            raise NotImplementedError(
                "mesh execution takes flat (n, d) data without categories "
                "or valid_mask (shards are the first hierarchy level)")
        if spec.plan != "auto":
            raise NotImplementedError(
                'mesh execution resolves its per-shard plan from max_k; '
                'use plan="auto"')
        n_shards = _mesh_shards(spec)
        labels = sharded_core(x, spec.k, spec.mesh,
                              data_axes=spec.data_axes, max_k=spec.max_k,
                              batched=spec.batched,
                              chunk_size=chunk_for(
                                  x.shape[0] // max(n_shards, 1), plan[0]),
                              **kw)
        plan = ((n_shards,) + plan) if n_shards > 1 else plan
    elif x.ndim == 3:
        if len(plan) > 1:
            raise NotImplementedError(
                "stacked (G, M, D) input requires a flat plan "
                f"(got plan={plan}); hierarchy nests via repeated calls")
        labels = aba_core(x, spec.k, vm, categories=cats,
                          n_categories=n_categories, **kw)
    elif len(plan) > 1:
        if vm is not None:
            raise NotImplementedError(
                "hierarchical plans do not support valid_mask; drop the "
                "padding rows instead")
        labels = hierarchical_core(x, plan, categories=cats,
                                   n_categories=n_categories,
                                   batched=spec.batched,
                                   chunk_size=chunk_for(x.shape[0], plan[0]),
                                   **kw)
    else:
        chunk = chunk_for(x.shape[0], spec.k)
        if chunk is not None:
            labels = aba_stream(x, spec.k, chunk, **kw)
        else:
            labels = aba_core(
                x[None], spec.k, None if vm is None else vm[None],
                categories=None if cats is None else cats[None],
                n_categories=n_categories, **kw)[0]

    # Finish the label computation before dispatching the statistics ops:
    # host-callback solvers (e.g. "scipy") deadlock on CPU if new work is
    # enqueued while their callback computation is still in flight.
    labels = jax.block_until_ready(labels)
    sizes, sd, rng = _result_stats(x, labels, spec.k, vm,
                                   diversity=spec.stats)
    return AnticlusterResult(
        labels=labels, cluster_sizes=sizes, diversity_sd=sd,
        diversity_range=rng, k=spec.k, plan=plan, solver=solver,
        variant=spec.variant)
