from repro.data.minibatch import ABABatchSequencer
from repro.data.folds import aba_folds, fold_partition
from repro.data import synthetic

__all__ = ["ABABatchSequencer", "aba_folds", "fold_partition", "synthetic"]
