from repro.data.minibatch import ABABatchSequencer
from repro.data.folds import aba_folds
from repro.data import synthetic

__all__ = ["ABABatchSequencer", "aba_folds", "synthetic"]
