"""ABA mini-batch sequencing for SGD -- the paper's headline ML application.

Each anticluster is one mini-batch: K = steps-per-epoch, so every batch is a
diverse, representative sample of the dataset (Section 1; the Imagenet32
rows of Tables 4/8 are exactly this workload).  Because ABA is deterministic,
the batch schedule is reproducible bit-for-bit after a restart -- the
fault-tolerance story of the training loop leans on this.

The sequencer owns ONE :class:`repro.anticluster.AnticlusterEngine` for the
whole training run: the initial partition compiles the shape-keyed
executable once, and per-epoch re-partitions (``epoch(i, features=...)`` /
``refresh``) warm-start the auction from the carried :class:`ABAState`
instead of re-tracing and cold-solving every epoch.  The compile-once
contract is load-bearing (``engine.compile_count`` stays 1 across epochs)
and pinned by ``tests/test_engine.py``.

Two modes:
  * single-host: hierarchical ABA over the example embeddings;
  * sharded: each data-parallel shard anticlusters its local rows via
    ``repro.core.sharded.sharded_core`` / ``anticluster(x, spec)`` with
    ``spec.mesh`` (collective-free; the host sharding is the top hierarchy
    level).
"""

from __future__ import annotations

import warnings

import numpy as np
import jax.numpy as jnp

from repro.anticluster import AnticlusterEngine, AnticlusterSpec
from repro.core.objective import diversity_per_cluster


def _auto_or_flat_spec(k: int, max_k: int, chunk_size="auto", mesh=None,
                       data_axes="auto") -> AnticlusterSpec:
    """Auto-plan spec, falling back to the flat path when k is unfactorable.

    ``default_plan`` enforces its max_k contract by raising (e.g. prime
    k > max_k).  Here k is derived from the data size, not chosen by the
    user, so a slow-but-correct flat solve beats a crash -- but loudly.
    ``chunk_size`` defaults to "auto": epoch-scale datasets stream the
    full-data level in fixed-size chunks (``repro.core.aba.aba_stream``)
    instead of materializing the permuted copy; small datasets stay dense.
    ``mesh`` distributes the solve (shard-local streaming composes); a k
    that cannot be placed on the mesh (not divisible by the shard count, or
    an unfactorable per-shard k) falls back to the local flat solve, again
    loudly.
    """
    if mesh is not None:
        from repro.sharding.specs import resolve_data_axes
        resolve_data_axes(mesh, data_axes)  # bad axes raise; no fallback
    spec = AnticlusterSpec(k=k, plan="auto", max_k=max_k,
                           chunk_size=chunk_size, mesh=mesh,
                           data_axes=data_axes)
    try:
        spec.resolve_plan()
        return spec
    except ValueError:
        where = ("placement on the mesh" if mesh is not None
                 else f"hierarchical plan with factors <= {max_k}")
        warnings.warn(
            f"k={k} has no {where}; falling back to the flat single-level "
            "single-device solve (slower at this k)",
            RuntimeWarning, stacklevel=3)
        return spec.evolve(plan=None, mesh=None)


def build_batch_schedule(labels: np.ndarray, k: int):
    """Anticluster labels -> per-batch index arrays (the batch membership).

    One batch per anticluster, rows in stable-sort order: a (k, n/k) array
    when k divides n, else a ragged list of per-batch index arrays
    (floor/ceil sizes -- a grown sequencer).  Shared by
    :class:`ABABatchSequencer` and :class:`repro.train.pipeline.ABAPipeline`
    so the two schedules agree bit-for-bit by construction.
    """
    labels = np.asarray(labels)
    order = np.argsort(labels, kind="stable")
    sizes = np.bincount(labels, minlength=k)
    if sizes.min() == sizes.max():
        # anticluster sizes are all exactly batch_size when K | N; a
        # 2D array keeps the historical batches contract
        return order.reshape(k, -1)
    # floor/ceil batch sizes: the schedule is ragged (list of index arrays)
    return np.split(order, np.cumsum(sizes)[:-1])


def epoch_order(seed: int, epoch_idx: int, k: int) -> np.ndarray:
    """The deterministic per-epoch batch order (counter-based rng).

    Shared by the sequencer, the pipeline and ``launch.train``'s
    restore-replay: the permutation depends only on ``(seed, epoch_idx)``.
    """
    return np.random.default_rng(seed * 100003 + epoch_idx).permutation(k)


class ABABatchSequencer:
    """Deterministic diverse mini-batch schedule over a dataset.

    Holds one :class:`AnticlusterEngine` for the training run.  The
    constructor's cold partition compiles the executable; every later
    re-partition (``refresh`` / ``epoch(i, features=...)`` on drifted
    embeddings) reuses it with warm-started auction prices -- zero retraces
    after epoch 0 (``self.engine.compile_count == 1``), which fixes the old
    per-epoch behaviour of re-entering jit with fresh tracers for an
    identical shape.

    Args:
      features: (N, D) embedding used for anticlustering (e.g. the doc/topic
        features from synthetic.lm_token_stream, pixel features, or an
        encoder embedding).
      batch_size: examples per step; K = floor(N / batch_size) anticlusters.
      epoch_shuffle: reshuffle the *order of batches* per epoch with a
        counter-based rng (batch membership stays fixed and deterministic).
      chunk_size: streaming execution for epoch-scale feature sets (see
        ``AnticlusterSpec.chunk_size``); "auto" engages only at scale.
      mesh: optional ``jax.sharding.Mesh`` -- the engine compiles one
        ``shard_map`` executable and carries per-shard warm prices
        (:class:`repro.anticluster.ShardedABAState`) across epochs, so each
        data-parallel shard re-partitions its local rows collective-free.
        K must be divisible by the shard count (else a loud flat fallback).
      data_axes: mesh axes sharding the rows ("auto": whichever of
        ('pod', 'data') the mesh has; explicit absent axes raise).
    """

    def __init__(self, features: np.ndarray, batch_size: int, *,
                 max_k: int = 512, seed: int = 0, chunk_size="auto",
                 mesh=None, data_axes="auto"):
        n = features.shape[0]
        self.batch_size = batch_size
        self.k = max(n // batch_size, 1)
        self.n_used = self.k * batch_size
        self.seed = seed
        self.engine = AnticlusterEngine(
            _auto_or_flat_spec(self.k, max_k, chunk_size, mesh=mesh,
                               data_axes=data_axes))
        self.result, self.state = self.engine.partition(
            jnp.asarray(features[:self.n_used]))
        self._features = features
        self._sig = ((self.n_used,) + tuple(np.shape(features))[1:],
                     jnp.dtype(self.engine.spec.dtype).name)
        self._rebuild_batches()

    def _check_signature(self, features: np.ndarray):
        """Refuse features that don't match the engine's compiled signature.

        The engine keys executables by (shape, dtype): a drifted-embedding
        refresh with a different row count or width would *silently retrace*
        (the carried flat prices are ``(1, k)`` -- independent of n and d --
        so the state check alone cannot catch it) and quietly break the
        compile-once contract.  Raise up front with the expected signature
        instead; build a fresh sequencer for a genuinely new geometry.
        """
        shape, dtype = self._sig
        got = tuple(np.shape(features))
        if np.asarray(features).dtype.kind not in "fiu":
            raise TypeError(
                f"features dtype {np.asarray(features).dtype} is not "
                f"numeric; the engine solves {dtype} embeddings")
        if got[0] < shape[0] or got[1:] != shape[1:]:
            raise ValueError(
                f"features of shape {got} do not match the engine's "
                f"compiled signature {shape} (>= {shape[0]} rows of "
                f"trailing shape {shape[1:]}): a refresh must keep the "
                "partition geometry -- build a new ABABatchSequencer for a "
                "different dataset shape")

    def _rebuild_batches(self):
        self.batches = build_batch_schedule(np.asarray(self.result.labels),
                                            self.k)

    def refresh(self, features: np.ndarray):
        """Warm re-partition on updated (same-shape) features.

        The carried :class:`ABAState` warm-starts every batch LAP; the
        engine's compiled executable is reused as-is (no retrace).  Features
        whose shape/dtype don't match the compiled signature raise a
        ``ValueError`` up front (they would silently retrace otherwise).
        Returns the new :class:`AnticlusterResult`.
        """
        self._check_signature(features)
        self.result, self.state = self.engine.repartition(
            jnp.asarray(features[:self.n_used]), self.state)
        self._features = features
        self._rebuild_batches()
        return self.result

    def grow(self, added: np.ndarray):
        """Absorb newly arrived examples into the live batch schedule.

        Routes through :meth:`AnticlusterEngine.update`: the arrivals are
        placed by the restricted warm-price auction against the carried
        per-batch centroids instead of re-solving the whole epoch (a delta
        above ``spec.update_threshold`` falls back to a full warm
        repartition, loudly).  K (steps per epoch) stays fixed; batch sizes
        become floor/ceil of the new N/K, so the schedule turns ragged.
        Returns the new :class:`AnticlusterResult` (``.updated`` says which
        path ran).  Not available under ``mesh`` (the delta subsystem is
        single-device); drifted-feature refreshes still go through
        :meth:`refresh`.
        """
        self.result, new_x, self.state = self.engine.update(
            jnp.asarray(self._features[: self.n_used]), self.state,
            added=jnp.asarray(added, dtype=self.engine.spec.dtype))
        self._features = np.asarray(new_x)
        self.n_used = self._features.shape[0]
        # the grown geometry is the engine's signature from here on
        self._sig = ((self.n_used,) + self._features.shape[1:], self._sig[1])
        self._rebuild_batches()
        return self.result

    def diversity_stats(self):
        f = jnp.asarray(self._features[:self.n_used])
        lab = np.zeros(self.n_used, np.int32)
        for b, idx in enumerate(self.batches):
            lab[idx] = b
        div = np.asarray(diversity_per_cluster(f, jnp.asarray(lab), self.k))
        return float(div.std()), float(div.max() - div.min())

    def epoch(self, epoch_idx: int, features: np.ndarray | None = None):
        """Batch index arrays for one epoch; order rotated deterministically.

        Pass ``features`` (same shape, drifted values -- e.g. the encoder
        embedding after the previous epoch's updates) to warm re-partition
        first; omit it to reuse the existing batch membership.  Returns a
        list (not a generator) so the re-partition happens eagerly -- the
        sequencer's ``result``/``state``/``diversity_stats`` reflect the new
        epoch immediately, whether or not the batches are consumed.
        """
        if features is not None:
            self.refresh(features)
        return [self.batches[b]
                for b in epoch_order(self.seed, epoch_idx, self.k)]

    def __len__(self):
        return self.k


def random_sequencer_batches(n: int, batch_size: int, seed: int = 0):
    """Baseline: the standard random-shuffle batching."""
    k = n // batch_size
    rng = np.random.default_rng(seed)
    order = rng.permutation(k * batch_size)
    return order.reshape(k, batch_size)
