"""Representative K-fold cross-validation via anticlustering (paper Section 1:
Papenberg & Klau's CV application).  Each fold is an anticluster -> folds
mirror the full data distribution, and with ``categories`` (e.g. class
labels) the folds are also stratified exactly (constraint (5))."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.aba import aba
from repro.core.hierarchical import aba_auto


def aba_folds(features: np.ndarray, n_folds: int, *,
              categories: np.ndarray | None = None, seed: int = 0):
    """Returns fold labels (N,) int32 in [0, n_folds)."""
    x = jnp.asarray(features)
    if categories is not None:
        g = int(categories.max()) + 1
        labels = aba(x, n_folds, categories=jnp.asarray(categories),
                     n_categories=g)
    else:
        labels = aba_auto(x, n_folds)
    return np.asarray(labels)


def fold_splits(labels: np.ndarray, n_folds: int):
    """Yield (train_idx, val_idx) per fold."""
    for f in range(n_folds):
        val = np.flatnonzero(labels == f)
        tr = np.flatnonzero(labels != f)
        yield tr, val
