"""Representative K-fold cross-validation via anticlustering (paper Section 1:
Papenberg & Klau's CV application).  Each fold is an anticluster -> folds
mirror the full data distribution, and with ``categories`` (e.g. class
labels) the folds are also stratified exactly (constraint (5)).

Built on :class:`repro.anticluster.AnticlusterEngine`: a CV harness that
re-builds folds repeatedly (per seed sweep, per feature-set revision) passes
one :func:`fold_engine` instance to every :func:`aba_folds` call and pays
the compile exactly once; one-off calls construct a throwaway engine
internally (same labels either way -- a cold engine partition is
bit-identical to one-shot ``anticluster``)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.anticluster import AnticlusterEngine


def fold_engine(n_folds: int, *, categories: np.ndarray | None = None,
                max_k: int = 512, chunk_size="auto", mesh=None,
                data_axes="auto") -> AnticlusterEngine:
    """An :class:`AnticlusterEngine` configured for ``n_folds`` CV folds.

    Reuse it across repeated ``aba_folds`` calls on same-shaped features to
    amortize compilation (``aba_folds`` itself always runs the cold
    ``partition`` so fold labels stay reproducible run to run; drive
    ``engine.repartition`` directly if you want warm-started prices between
    successive builds and accept eps-optimal label drift).

    ``mesh`` builds the folds distributed (each data-parallel shard solves
    its local rows; ``categories`` then stratify per shard); ``n_folds``
    must be divisible by the shard count or the engine falls back to the
    single-device flat solve with a RuntimeWarning.
    """
    from repro.data.minibatch import _auto_or_flat_spec
    spec = _auto_or_flat_spec(n_folds, max_k, chunk_size, mesh=mesh,
                              data_axes=data_axes).evolve(
        categories=None if categories is None else jnp.asarray(categories))
    return AnticlusterEngine(spec)


def aba_folds(features: np.ndarray, n_folds: int, *,
              categories: np.ndarray | None = None, seed: int = 0,
              max_k: int = 512,
              engine: AnticlusterEngine | None = None):
    """Returns fold labels (N,) int32 in [0, n_folds).

    Routes through the engine (and thereby the spec dispatcher), so
    ``n_folds`` larger than ``max_k`` takes the hierarchical plan --
    including with ``categories``: each level stratifies within its groups
    and ceil/floor compose across levels, so the exact per-category
    constraint (5) holds for the final folds (see
    ``repro.core.hierarchical``).  Legacy behaviour silently dropped the
    hierarchy whenever categories were given.

    ``engine`` (from :func:`fold_engine`) lets repeated callers share one
    compiled executable (a cold partition per call -- deterministic labels);
    when omitted a fresh engine is built per call.
    """
    del seed  # ABA is deterministic; kept for API stability
    if engine is None:
        engine = fold_engine(n_folds, categories=categories, max_k=max_k,
                             chunk_size="auto")
    elif engine.spec.k != n_folds:
        raise ValueError(
            f"engine was built for k={engine.spec.k} folds but "
            f"n_folds={n_folds} was requested; build it with "
            f"fold_engine({n_folds}, ...)")
    elif (engine.spec.categories is None) != (categories is None) or (
            categories is not None
            and not np.array_equal(np.asarray(engine.spec.categories),
                                   np.asarray(categories))):
        raise ValueError(
            "engine stratification does not match this call: pass the same "
            "categories to fold_engine(...) and aba_folds(...)")
    res, _state = engine.partition(jnp.asarray(features))
    return np.asarray(res.labels)


def fold_partition(features: np.ndarray, n_folds: int, *, max_k: int = 512,
                   chunk_size="auto"):
    """Live representative folds: an :class:`IncrementalPartition`.

    For CV harnesses whose dataset changes between sweeps (arriving
    samples, retracted rows): ``part.update(added=..., removed=...)``
    re-balances the folds through the delta path instead of rebuilding
    from scratch, and ``part.labels`` / :func:`fold_splits` read the live
    assignment.  Stratification is not supported on the delta path --
    stratified folds stay on :func:`aba_folds` + :func:`fold_engine`.
    """
    from repro.data.minibatch import _auto_or_flat_spec
    from repro.incremental import IncrementalPartition

    spec = _auto_or_flat_spec(n_folds, max_k, chunk_size)
    return IncrementalPartition(jnp.asarray(features), spec)


def fold_splits(labels: np.ndarray, n_folds: int):
    """Yield (train_idx, val_idx) per fold."""
    for f in range(n_folds):
        val = np.flatnonzero(labels == f)
        tr = np.flatnonzero(labels != f)
        yield tr, val
