"""Representative K-fold cross-validation via anticlustering (paper Section 1:
Papenberg & Klau's CV application).  Each fold is an anticluster -> folds
mirror the full data distribution, and with ``categories`` (e.g. class
labels) the folds are also stratified exactly (constraint (5))."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.anticluster import AnticlusterSpec, anticluster


def aba_folds(features: np.ndarray, n_folds: int, *,
              categories: np.ndarray | None = None, seed: int = 0,
              max_k: int = 512):
    """Returns fold labels (N,) int32 in [0, n_folds).

    Routes through the spec dispatcher, so ``n_folds`` larger than ``max_k``
    takes the hierarchical plan -- including with ``categories``: each level
    stratifies within its groups and ceil/floor compose across levels, so the
    exact per-category constraint (5) holds for the final folds (see
    ``repro.core.hierarchical``).  Legacy behaviour silently dropped the
    hierarchy whenever categories were given.
    """
    del seed  # ABA is deterministic; kept for API stability
    from repro.data.minibatch import _auto_or_flat_spec
    spec = _auto_or_flat_spec(n_folds, max_k).replace(
        categories=None if categories is None else jnp.asarray(categories))
    return np.asarray(anticluster(jnp.asarray(features), spec).labels)


def fold_splits(labels: np.ndarray, n_folds: int):
    """Yield (train_idx, val_idx) per fold."""
    for f in range(n_folds):
        val = np.flatnonzero(labels == f)
        tr = np.flatnonzero(labels != f)
        yield tr, val
