"""Synthetic dataset generators matched to the paper's Table 2 scales.

The container is offline, so the UCI / ImageNet datasets are replaced by
generators with identical (N, D) and qualitatively similar structure:
Gaussian mixtures (tabular clusters), low-rank + noise (image-embedding
like), binary occurrence matrices (Plants-like), and heavy-tailed financial
rows.  Each paper dataset name maps to a preset so the benchmark tables line
up row-for-row with the paper.
"""

from __future__ import annotations

import numpy as np

# (N, D, kind) per paper Table 2
PRESETS = {
    "abalone":    (4_177, 10, "mixture"),
    "travel":     (5_454, 24, "mixture"),
    "facebook":   (7_050, 13, "mixture"),
    "frogs":      (7_195, 22, "mixture"),
    "electric":   (10_000, 12, "mixture"),
    "npi":        (10_440, 40, "binary"),
    "pulsar":     (17_898, 8, "mixture"),
    "creditcard": (30_000, 24, "mixture"),
    "adult":      (32_561, 110, "binary"),
    "plants":     (34_781, 70, "binary"),
    "bank":       (45_211, 53, "mixture"),
    "cifar10":    (50_000, 3_072, "lowrank"),
    "mnist":      (60_000, 784, "lowrank"),
    "survival":   (110_204, 4, "mixture"),
    "diabetes":   (253_680, 22, "mixture"),
    "music":      (515_345, 91, "lowrank"),
    "covtype":    (581_012, 55, "mixture"),
    "imagenet8":  (1_281_167, 192, "lowrank"),
    "imagenet32": (1_281_167, 3_072, "lowrank"),
    "census":     (2_458_285, 68, "binary"),
    "finance":    (6_362_620, 12, "heavytail"),
}


def make(kind: str, n: int, d: int, seed: int = 0,
         n_clusters: int = 10) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "mixture":
        centers = rng.normal(0, 3.0, size=(n_clusters, d))
        labels = rng.integers(0, n_clusters, size=n)
        x = centers[labels] + rng.normal(size=(n, d))
    elif kind == "lowrank":
        r = max(4, min(d // 8, 64))
        u = rng.normal(size=(n, r))
        v = rng.normal(size=(r, d))
        x = u @ v + 0.3 * rng.normal(size=(n, d))
    elif kind == "binary":
        p = rng.beta(0.5, 2.0, size=d)
        x = (rng.random((n, d)) < p).astype(np.float64)
    elif kind == "heavytail":
        x = rng.standard_t(df=3, size=(n, d)) * rng.gamma(2.0, 1.0, size=(1, d))
    else:
        raise ValueError(kind)
    # paper preprocessing: standardize (or leave binaries as-is, like [0,1])
    if kind != "binary":
        x = (x - x.mean(0)) / np.maximum(x.std(0), 1e-9)
    return x.astype(np.float32)


def load(name: str, seed: int = 0, max_n: int | None = None) -> np.ndarray:
    n, d, kind = PRESETS[name]
    if max_n:
        n = min(n, max_n)
    return make(kind, n, d, seed=seed)


def lm_token_stream(n_docs: int, seq_len: int, vocab: int, seed: int = 0,
                    n_topics: int = 16):
    """Synthetic LM corpus with topic structure: each doc draws a topic, and
    tokens follow a topic-specific Zipf over a topic-local vocabulary slice.
    Returns (tokens (n_docs, seq_len) int32, doc_features (n_docs, n_topics)
    float32) -- the features are the embeddings ABA batches on."""
    rng = np.random.default_rng(seed)
    topics = rng.integers(0, n_topics, size=n_docs)
    mix = rng.dirichlet(np.ones(n_topics) * 0.3, size=n_docs)
    mix[np.arange(n_docs), topics] += 1.0
    mix /= mix.sum(1, keepdims=True)
    base = rng.zipf(1.5, size=(n_docs, seq_len)).astype(np.int64)
    offset = (topics * (vocab // n_topics))[:, None]
    tokens = (offset + (base % (vocab // n_topics))).astype(np.int32)
    return tokens, mix.astype(np.float32)
