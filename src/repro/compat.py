"""Version-compatibility shims: single import site for moving jax APIs.

``shard_map`` lived in ``jax.experimental.shard_map`` through the 0.4.x
series (with a ``check_rep`` kwarg) and later graduated to the top-level
``jax`` namespace (where the kwarg became ``check_vma``).  Everything in this
repo imports it from here so the rest of the code can use either kwarg
spelling regardless of the installed jax.
"""

from __future__ import annotations

try:  # jax >= 0.6: top-level export, `check_vma` kwarg
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental module, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


# Pallas-TPU compiler params: `TPUCompilerParams` on jax 0.4.x, renamed to
# `CompilerParams` later.  Kernels import the class from here.
from jax.experimental.pallas import tpu as _pltpu

TPUCompilerParams = getattr(_pltpu, "CompilerParams",
                            getattr(_pltpu, "TPUCompilerParams", None))


def shard_map(f, mesh, in_specs, out_specs, **kw):
    """``shard_map`` accepting both ``check_rep`` and ``check_vma``."""
    for alias in ("check_vma", "check_rep"):
        if alias in kw and alias != _CHECK_KW:
            kw[_CHECK_KW] = kw.pop(alias)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
