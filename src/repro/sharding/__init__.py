from repro.sharding.specs import (LOGICAL, to_pspec, logical_to_sharding,
                                  tree_pspecs)

__all__ = ["LOGICAL", "to_pspec", "logical_to_sharding", "tree_pspecs"]
