"""Logical axis -> mesh axis rules (MaxText-style, reduced vocabulary).

Every parameter/activation dim is tagged with a logical axis:

  fsdp   ZeRO-3 weight sharding over the data-parallel axes ('pod','data')
  tp     tensor parallel over 'model' (heads / ff / vocab / experts / d_inner)
  dp     batch dim of activations over ('pod','data')
  sp     long sequences (decode KV caches) over 'model' (flash-decode style)
  None   replicated

Axes missing from the mesh (e.g. 'pod' on the single-pod mesh) are dropped.
Non-divisible dims (40 heads over 16-way 'model') rely on GSPMD uneven
sharding; the padding waste shows up in the MODEL_FLOPS/HLO_FLOPs ratio and
is discussed in EXPERIMENTS.md.
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import jax

LOGICAL = {
    "fsdp": ("pod", "data"),
    "dp": ("pod", "data"),
    "tp": ("model",),
    "sp": ("model",),
    None: (),
}


def _resolve(tag, axis_names):
    axes = tuple(a for a in LOGICAL[tag] if a in axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def to_pspec(tags: tuple, axis_names) -> P:
    """('fsdp', 'tp') -> PartitionSpec(('pod','data'), 'model')."""
    return P(*(_resolve(t, axis_names) for t in tags))


def logical_to_sharding(tags: tuple, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, to_pspec(tags, mesh.axis_names))


def tree_pspecs(tag_tree, axis_names):
    """Map a pytree of logical-tag tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda tags: to_pspec(tags, axis_names), tag_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(t, (str, type(None))) for t in x))
