"""Logical axis -> mesh axis rules (MaxText-style, reduced vocabulary).

Every parameter/activation dim is tagged with a logical axis:

  fsdp   ZeRO-3 weight sharding over the data-parallel axes ('pod','data')
  tp     tensor parallel over 'model' (heads / ff / vocab / experts / d_inner)
  dp     batch dim of activations over ('pod','data')
  sp     long sequences (decode KV caches) over 'model' (flash-decode style)
  None   replicated

Axes missing from the mesh (e.g. 'pod' on the single-pod mesh) are dropped.
Non-divisible dims (40 heads over 16-way 'model') rely on GSPMD uneven
sharding; the padding waste shows up in the MODEL_FLOPS/HLO_FLOPs ratio and
is discussed in EXPERIMENTS.md.
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import jax

LOGICAL = {
    "fsdp": ("pod", "data"),
    "dp": ("pod", "data"),
    "tp": ("model",),
    "sp": ("model",),
    None: (),
}


def _resolve(tag, axis_names):
    axes = tuple(a for a in LOGICAL[tag] if a in axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def to_pspec(tags: tuple, axis_names) -> P:
    """('fsdp', 'tp') -> PartitionSpec(('pod','data'), 'model')."""
    return P(*(_resolve(t, axis_names) for t in tags))


def logical_to_sharding(tags: tuple, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, to_pspec(tags, mesh.axis_names))


def tree_pspecs(tag_tree, axis_names):
    """Map a pytree of logical-tag tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda tags: to_pspec(tags, axis_names), tag_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(t, (str, type(None))) for t in x))


# --- data-parallel placement of anticlustering sessions ---------------------

DATA_AXIS_CANDIDATES = ("pod", "data")


def resolve_data_axes(mesh: Mesh, data_axes="auto") -> tuple[str, ...]:
    """The concrete mesh axes that shard the data rows.

    ``"auto"`` (the :class:`repro.anticluster.AnticlusterSpec` default) takes
    whichever of the canonical data-parallel axes
    (:data:`DATA_AXIS_CANDIDATES`) exist on ``mesh`` -- the single-pod mesh
    simply has no ``'pod'`` axis.  An **explicit** tuple is validated
    strictly: naming an axis the mesh does not have raises with the offending
    names instead of silently dropping them (a typo'd axis would otherwise
    quietly change the shard count and therefore every label).
    """
    if data_axes is None or data_axes == "auto":
        axes = tuple(a for a in DATA_AXIS_CANDIDATES if a in mesh.axis_names)
        if not axes:
            raise ValueError(
                f"mesh axes {tuple(mesh.axis_names)} contain none of the "
                f"default data axes {DATA_AXIS_CANDIDATES}; pass data_axes "
                "naming the axis that shards the rows")
        return axes
    axes = (data_axes,) if isinstance(data_axes, str) else tuple(data_axes)
    missing = tuple(a for a in axes if a not in mesh.axis_names)
    if missing:
        raise ValueError(
            f"data_axes {missing} not present on the mesh (axes: "
            f"{tuple(mesh.axis_names)}); silently dropping them would "
            "change the shard count -- name only existing axes or use "
            'data_axes="auto"')
    if not axes:
        raise ValueError("data_axes must name at least one mesh axis")
    return axes


def shard_leading(mesh: Mesh, axes: tuple[str, ...], tree):
    """NamedShardings that shard every leaf's leading dim over ``axes``.

    The layout of a :class:`repro.anticluster.ShardedABAState`: per-shard
    price stacks ``(S, G_l, k_l)``, moment rows ``(S, d)`` / counts ``(S,)``
    and the row-sharded ``(n,)`` label vector all shard dimension 0 across
    the data-parallel axes and replicate the rest.
    """
    def leaf_sharding(leaf):
        ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        return NamedSharding(mesh, P(axes, *(None,) * (ndim - 1)))
    return jax.tree.map(leaf_sharding, tree)
