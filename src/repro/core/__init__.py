"""ABA core: the paper's primary contribution as composable JAX modules."""

from repro.core.aba import (aba, aba_batched, aba_reference,
                            interleave_permutation)
from repro.core.assignment import (AuctionConfig, assignment_value,
                                   auction_solve, auction_solve_factored,
                                   greedy_solve, scipy_solve)
from repro.core.hierarchical import aba_auto, default_plan, hierarchical_aba
from repro.core.objective import (balance_ok, centroids, cluster_sizes,
                                  cut_cost, diversity_per_cluster,
                                  diversity_stats, objective_centroid,
                                  objective_pairwise, total_pairwise)
from repro.core import baselines

__all__ = [
    "aba", "aba_batched", "aba_reference", "interleave_permutation",
    "AuctionConfig", "auction_solve", "auction_solve_factored",
    "greedy_solve", "scipy_solve", "assignment_value",
    "aba_auto", "default_plan", "hierarchical_aba", "balance_ok", "centroids",
    "cluster_sizes", "cut_cost", "diversity_per_cluster", "diversity_stats",
    "objective_centroid", "objective_pairwise", "total_pairwise", "baselines",
]
