"""ABA core: the paper's primary contribution as composable JAX modules.

``aba_core`` is the one rank-polymorphic implementation of Algorithm 1;
``aba_stream`` is its chunked matrix-free twin for million-scale flat inputs
(same per-batch step, O(chunk*d + k*d) working set);
``hierarchical_core`` stacks it per Section 4.4.  The legacy entry points
(``aba``, ``aba_batched``, ``hierarchical_aba``, ``aba_auto``) are deprecated
exact-parity shims -- new code goes through ``repro.anticluster``.
"""

from repro.core.aba import (aba, aba_batched, aba_core, aba_reference,
                            aba_stream, delta_moments,
                            interleave_permutation)
from repro.core.assignment import (AuctionConfig, assignment_value,
                                   auction_solve, auction_solve_factored,
                                   available_solvers, get_solver,
                                   greedy_solve, register_solver, scipy_solve,
                                   solve_restricted_slots)
from repro.core.hierarchical import (aba_auto, default_plan,
                                     hierarchical_aba, hierarchical_core)
from repro.core.objective import (balance_ok, centroids, cluster_sizes,
                                  cut_cost, diversity_per_cluster,
                                  diversity_stats, dual_certificate,
                                  objective_centroid, objective_pairwise,
                                  total_pairwise)
from repro.core import baselines

__all__ = [
    "aba", "aba_batched", "aba_core", "aba_reference", "aba_stream",
    "delta_moments", "interleave_permutation",
    "AuctionConfig", "auction_solve", "auction_solve_factored",
    "greedy_solve", "scipy_solve", "assignment_value",
    "register_solver", "get_solver", "available_solvers",
    "solve_restricted_slots",
    "aba_auto", "default_plan", "hierarchical_aba", "hierarchical_core",
    "balance_ok", "centroids",
    "cluster_sizes", "cut_cost", "diversity_per_cluster", "diversity_stats",
    "dual_certificate",
    "objective_centroid", "objective_pairwise", "total_pairwise", "baselines",
]
