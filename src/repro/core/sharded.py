"""Distributed ABA across the device mesh (multi-host / multi-pod).

Maps the paper's "subproblems can be solved in parallel" (Section 4.4) onto
``shard_map``: the data-parallel sharding of the dataset IS the first level of
the hierarchical decomposition.  Each data-parallel shard runs the local ABA
core on its local rows and produces ``K / n_shards`` local anticlusters;
global label = shard_offset + local label.

This is exactly the paper's multi-level scheme with a size-balanced (but not
distance-sorted) top level -- the quality impact is measured in
``benchmarks/fig7_hierarchical.py`` and is in line with the paper's Figure 7
observation that the decomposition barely moves the objective.

The mesh is an *orthogonal placement axis* of the same engine API, not a
special one-shot mode: everything the shard-local cores support composes with
the sharding --

* **streaming** (``chunk_size``): each shard runs ``repro.core.aba.aba_stream``
  over its local rows (per-shard working set O(chunk*d + k_local*d));
* **categories / valid_mask**: each shard stratifies / masks its local rows
  through the same ``aba_core`` machinery (stratification is then exact *per
  shard*; the shard level itself splits by data placement, not category);
* **warm starts** (``prices`` / ``return_state``): per-shard, per-level
  auction price stacks -- leading shard axis, laid out with
  ``jax.sharding`` -- thread through every local solve, which is what
  :class:`repro.anticluster.AnticlusterEngine` carries in its
  :class:`repro.anticluster.ShardedABAState` across ``repartition`` calls.

Used by ``repro.data`` to build diverse mini-batches for each data-parallel
group without any cross-host traffic (the collective-free fast path), by
``repro.serve`` for sharded warm lanes, and by ``launch/dryrun.py`` to lower
the ABA step on the production mesh.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.assignment import AuctionConfig
from repro.core.hierarchical import (default_plan, hierarchical_core,
                                     plan_price_shapes)
from repro.core.aba import aba_core, aba_stream
from repro.sharding.specs import resolve_data_axes


def sharded_price_shapes(plan: tuple[int, ...],
                         n_shards: int) -> tuple[tuple[int, ...], ...]:
    """Per-level price-stack shapes carried by a sharded session.

    Each level's per-shard shape (:func:`plan_price_shapes`) gains a leading
    shard axis: level l is ``(n_shards, prod(plan[:l-1]), plan[l-1])``.
    """
    return tuple((n_shards,) + s for s in plan_price_shapes(plan))


def sharded_core(
    x: jnp.ndarray,
    k: int,
    mesh: Mesh,
    *,
    data_axes="auto",
    max_k: int = 512,
    variant: str = "auto",
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
    batched: bool = True,
    chunk_size: int | None = None,
    categories: jnp.ndarray | None = None,
    n_categories: int = 0,
    fair_codes: jnp.ndarray | None = None,
    n_fair_codes: int = 0,
    valid_mask: jnp.ndarray | None = None,
    prices: tuple[jnp.ndarray, ...] | None = None,
    return_state: bool = False,
):
    """Partition sharded ``x`` (n, d) into k anticlusters; returns (n,) labels.

    ``k`` must be divisible by the total data-parallel shard count, and ``n``
    by the shard count (pad the dataset and pass ``valid_mask`` if needed);
    each shard owns n/n_shards rows.  ``data_axes`` follows
    :func:`repro.sharding.specs.resolve_data_axes` -- ``"auto"`` takes
    whichever of ('pod', 'data') the mesh has, an explicit tuple is validated
    strictly (absent axes raise, naming the offenders).  ``batched`` routes
    each shard's hierarchical levels through the single-call batched auction
    engine (see ``hierarchical_core``).  ``chunk_size`` streams each shard's
    *local* full-data level through ``repro.core.aba.aba_stream`` (per-shard
    working set O(chunk_size*d + k_local*d)); the shard level itself is
    already collective-free, so streaming composes with it.

    ``categories`` (with static ``n_categories``) stratifies each shard's
    local rows exactly (Section 4.3 per shard); ``fair_codes`` /
    ``n_fair_codes`` thread the multi-attribute fairness quota codes (see
    ``aba_core``) per shard; ``valid_mask`` marks padding rows (flat
    per-shard plans only -- the hierarchy's regrouping does not carry
    masks).  All are (n,) / (n, A) vectors sharded alongside ``x``, and all
    of them *stream* when ``chunk_size`` is set (the per-shard local level
    runs the chunked categorical ``aba_stream``).

    ``prices`` warm-starts every shard's per-level auctions from a carried
    per-shard price stack (level shapes from :func:`sharded_price_shapes`;
    ``None`` -- or all-zero stacks -- is the bit-identical cold path).
    ``return_state`` additionally returns ``{"prices": per-level (S, G_l,
    k_l) tuple, "moment_sum": (S, d) per-shard feature sums over valid rows,
    "moment_count": (S,)}`` -- the carried state of a distributed session.
    """
    axes = resolve_data_axes(mesh, data_axes)
    n_shards = math.prod(mesh.shape[a] for a in axes)
    if k % n_shards:
        raise ValueError(f"k={k} must be divisible by shard count {n_shards}")
    n, d = x.shape
    if n % n_shards:
        raise ValueError(
            f"n={n} rows must be divisible by shard count {n_shards} "
            "(pad the dataset and mark the padding with valid_mask)")
    k_local = k // n_shards
    plan = default_plan(k_local, max_k=max_k)
    if valid_mask is not None and len(plan) > 1:
        raise NotImplementedError(
            f"valid_mask needs a flat per-shard plan (k/n_shards={k_local} "
            f"resolved to {plan}); raise max_k or drop the padding rows")
    if categories is not None and n_categories <= 0:
        raise ValueError("n_categories must be set with categories")
    if (not batched) and (return_state or prices is not None):
        raise NotImplementedError(
            "price/state threading requires batched=True levels")
    kw = dict(variant=variant, solver=solver, auction_config=auction_config)

    has_cats = categories is not None
    has_codes = fair_codes is not None
    has_vm = valid_mask is not None
    has_prices = prices is not None
    n_levels = len(plan)

    operands = [x]
    in_specs = [P(axes, None)]
    if has_cats:
        operands.append(jnp.asarray(categories, jnp.int32))
        in_specs.append(P(axes))
    if has_codes:
        operands.append(jnp.asarray(fair_codes, jnp.int32))
        in_specs.append(P(axes, None))
    if has_vm:
        operands.append(jnp.asarray(valid_mask, jnp.bool_))
        in_specs.append(P(axes))
    if has_prices:
        if len(prices) != n_levels:
            raise ValueError(
                f"prices carries {len(prices)} levels for a {n_levels}-level "
                f"per-shard plan {plan}")
        operands.extend(jnp.asarray(p, jnp.float32) for p in prices)
        in_specs.extend(P(axes, None, None) for _ in prices)

    def local_fn(*args):
        it = iter(args)
        x_local = next(it)
        xs = x_local.reshape((-1, x_local.shape[-1]))
        cl = next(it).reshape(-1) if has_cats else None
        fl = (next(it).reshape(-1, fair_codes.shape[-1]) if has_codes
              else None)
        vl = next(it).reshape(-1) if has_vm else None
        p_local = tuple(p[0] for p in it) if has_prices else None

        p0 = None if p_local is None else p_local[0]
        if n_levels == 1 and chunk_size is not None:
            # each shard streams its local rows -- categories / fair codes /
            # mask included (the chunked rank-in-category rearrangement
            # keeps per-shard labels bit-identical to the dense local core
            # at chunk >= n_local)
            local, st = aba_stream(xs, k_local, chunk_size,
                                   categories=cl, n_categories=n_categories,
                                   fair_codes=fl, n_fair_codes=n_fair_codes,
                                   valid_mask=vl, prices=p0,
                                   return_state=True, **kw)
            p_out, mu = (st["prices"],), st["mu"]
        elif n_levels == 1:
            local, st = aba_core(
                xs[None], k_local,
                None if vl is None else vl[None],
                categories=None if cl is None else cl[None],
                n_categories=n_categories,
                fair_codes=None if fl is None else fl[None],
                n_fair_codes=n_fair_codes, prices=p0,
                return_state=True, **kw)
            local = local[0]
            p_out, mu = (st["prices"],), st["mu"][0]
        elif batched:
            local, st = hierarchical_core(
                xs, plan, categories=cl, n_categories=n_categories,
                fair_codes=fl, n_fair_codes=n_fair_codes,
                batched=True, chunk_size=chunk_size,
                prices=p_local, return_state=True, **kw)
            p_out, mu = st["prices"], st["mu"]
        else:
            # legacy vmap-per-group levels: no state threading (benchmarks)
            local = hierarchical_core(
                xs, plan, categories=cl, n_categories=n_categories,
                batched=False, chunk_size=chunk_size, **kw)
            p_out = tuple(jnp.zeros(s, jnp.float32)
                          for s in plan_price_shapes(plan))
            mu = jnp.mean(xs, axis=0)

        offset = jnp.int32(0)
        for a in axes:
            offset = offset * mesh.shape[a] + jax.lax.axis_index(a)
        labels = (offset * k_local + local).reshape(x_local.shape[:-1])
        cnt = (jnp.asarray(float(xs.shape[0]), jnp.float32) if vl is None
               else jnp.sum(vl, dtype=jnp.float32))
        outs = (labels, tuple(p[None] for p in p_out),
                (mu * cnt)[None], cnt[None])
        return outs

    out_specs = (P(axes), tuple(P(axes, None, None) for _ in range(n_levels)),
                 P(axes, None), P(axes))
    fn = shard_map(local_fn, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=out_specs, check_vma=False)
    labels, p_out, msum, mcnt = fn(*operands)
    if return_state:
        return labels, {"prices": p_out, "moment_sum": msum,
                        "moment_count": mcnt}
    return labels


def sharded_aba(x: jnp.ndarray, k: int, mesh: Mesh, **kw):
    """Deprecated: use ``repro.anticluster.anticluster`` with ``spec.mesh``
    (one-shot) or ``repro.anticluster.AnticlusterEngine`` with a mesh spec
    (warm-startable sessions); ``sharded_core`` stays the raw jit-able
    labels."""
    from repro.core.aba import _deprecated
    _deprecated("sharded_aba",
                "repro.anticluster.anticluster(x, spec) with spec.mesh")
    return sharded_core(x, k, mesh, **kw)


def sharded_aba_lowerable(mesh: Mesh, n: int, d: int, k: int,
                          **kw):
    """(jitted fn, arg specs) for dry-run lowering of the ABA data step."""
    fn = functools.partial(sharded_core, k=k, mesh=mesh, **kw)
    axes = resolve_data_axes(mesh, kw.get("data_axes", "auto"))
    jitted = jax.jit(
        fn,
        in_shardings=NamedSharding(mesh, P(axes, None)),
    )
    spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
    return jitted, spec
