"""Distributed ABA across the device mesh (multi-host / multi-pod).

Maps the paper's "subproblems can be solved in parallel" (Section 4.4) onto
``shard_map``: the data-parallel sharding of the dataset IS the first level of
the hierarchical decomposition.  Each ('pod','data') shard runs
``hierarchical_aba`` on its local rows and produces ``K / n_shards`` local
anticlusters; global label = shard_offset + local label.

This is exactly the paper's multi-level scheme with a size-balanced (but not
distance-sorted) top level -- the quality impact is measured in
``benchmarks/fig7_hierarchical.py`` and is in line with the paper's Figure 7
observation that the decomposition barely moves the objective.

Used by ``repro.data`` to build diverse mini-batches for each data-parallel
group without any cross-host traffic (the collective-free fast path), and by
``launch/dryrun.py`` to lower the ABA step on the production mesh.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.assignment import AuctionConfig
from repro.core.hierarchical import default_plan, hierarchical_core
from repro.core.aba import aba_core, aba_stream


def sharded_core(
    x: jnp.ndarray,
    k: int,
    mesh: Mesh,
    *,
    data_axes: tuple[str, ...] = ("pod", "data"),
    max_k: int = 512,
    variant: str = "auto",
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
    batched: bool = True,
    chunk_size: int | None = None,
):
    """Partition sharded ``x`` (n, d) into k anticlusters; returns (n,) labels.

    ``k`` must be divisible by the total data-parallel shard count; each shard
    owns n/n_shards rows (pad the dataset first if needed).  ``batched``
    routes each shard's hierarchical levels through the single-call batched
    auction engine (see ``hierarchical_core``).  ``chunk_size`` streams each
    shard's *local* full-data level through ``repro.core.aba.aba_stream``
    (per-shard working set O(chunk_size*d + k_local*d)); the shard level
    itself is already collective-free, so streaming composes with it.
    """
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    n_shards = math.prod(mesh.shape[a] for a in axes)
    if k % n_shards:
        raise ValueError(f"k={k} must be divisible by shard count {n_shards}")
    k_local = k // n_shards
    plan = default_plan(k_local, max_k=max_k)
    kw = dict(variant=variant, solver=solver, auction_config=auction_config)

    def local_fn(x_local):
        # collapse the leading shard axes added by shard_map
        xs = x_local.reshape((-1, x_local.shape[-1]))
        if len(plan) == 1 and chunk_size is not None:
            local = aba_stream(xs, k_local, chunk_size, variant=variant,
                               solver=solver, auction_config=auction_config)
        elif len(plan) == 1:
            local = aba_core(xs[None], k_local, **kw)[0]
        else:
            local = hierarchical_core(xs, plan, batched=batched,
                                      chunk_size=chunk_size, **kw)
        offset = jnp.int32(0)
        for a in axes:
            offset = offset * mesh.shape[a] + jax.lax.axis_index(a)
        return (offset * k_local + local).reshape(x_local.shape[:-1])

    spec = P(axes, None)
    fn = shard_map(local_fn, mesh=mesh, in_specs=(spec,), out_specs=P(axes),
                   check_vma=False)
    return fn(x)


def sharded_aba(x: jnp.ndarray, k: int, mesh: Mesh, **kw):
    """Deprecated: use ``repro.anticluster.anticluster`` with ``spec.mesh``
    (or ``sharded_core`` for the raw jit-able labels)."""
    from repro.core.aba import _deprecated
    _deprecated("sharded_aba",
                "repro.anticluster.anticluster(x, spec) with spec.mesh")
    return sharded_core(x, k, mesh, **kw)


def sharded_aba_lowerable(mesh: Mesh, n: int, d: int, k: int,
                          **kw):
    """(jitted fn, arg specs) for dry-run lowering of the ABA data step."""
    fn = functools.partial(sharded_core, k=k, mesh=mesh, **kw)
    jitted = jax.jit(
        fn,
        in_shardings=NamedSharding(mesh, P(("pod", "data") if "pod" in
                                           mesh.axis_names else ("data",), None)),
    )
    spec = jax.ShapeDtypeStruct((n, d), jnp.float32)
    return jitted, spec
