"""Anticlustering objectives and diversity statistics (paper Section 2 + Fact 1).

Two equivalent forms (Fact 1):
  pairwise form :  W(C) = sum_k sum_{i<i' in C_k} ||x_i - x_i'||^2
  centroid form :  W(C) = sum_k n_k * sum_{i in C_k} ||x_i - mu_k||^2

The paper's experiment tables report ``ofv`` as the *centroid* sum
``sum_k sum_{i in C_k} ||x_i - mu_k||^2`` (without the n_k factor, see
Section 5.3) while Table 11 (balanced k-cut) uses the pairwise W(C).  We
expose all three plus the per-cluster diversity stats (sd / range) used in
Tables 6 and 10.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k",))
def cluster_sizes(labels: jnp.ndarray, k: int) -> jnp.ndarray:
    return jnp.zeros((k,), jnp.int32).at[labels].add(1)


@functools.partial(jax.jit, static_argnames=("k",))
def centroids(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """(k, d) cluster centroids via segment-sum."""
    sums = jax.ops.segment_sum(x, labels, num_segments=k)
    counts = cluster_sizes(labels, k)
    return sums / jnp.maximum(counts, 1)[:, None].astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("k",))
def diversity_per_cluster(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """d_k = sum_{i in C_k} ||x_i - mu_k||^2  (the paper's per-cluster diversity)."""
    mu = centroids(x, labels, k)
    sq = jnp.sum((x - mu[labels]) ** 2, axis=-1)
    return jax.ops.segment_sum(sq, labels, num_segments=k)


@functools.partial(jax.jit, static_argnames=("k",))
def objective_centroid(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """sum_k sum_{i in C_k} ||x_i - mu_k||^2  -- the tables' ``ofv``."""
    return jnp.sum(diversity_per_cluster(x, labels, k))


@functools.partial(jax.jit, static_argnames=("k",))
def objective_pairwise(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """W(C) = sum_k n_k * d_k  (Fact 1) -- Table 11's W(C)."""
    div = diversity_per_cluster(x, labels, k)
    counts = cluster_sizes(labels, k).astype(x.dtype)
    return jnp.sum(counts * div)


@functools.partial(jax.jit, static_argnames=("k",))
def diversity_stats(x: jnp.ndarray, labels: jnp.ndarray, k: int):
    """(sd, range) of the k per-cluster diversities (Tables 6/10)."""
    div = diversity_per_cluster(x, labels, k)
    return jnp.std(div), jnp.max(div) - jnp.min(div)


@jax.jit
def total_pairwise(x: jnp.ndarray) -> jnp.ndarray:
    """sum_{i<i'} ||x_i - x_i'||^2 = N * sum_i ||x_i - mu||^2 (Fact 1, K=1)."""
    mu = jnp.mean(x, axis=0)
    return x.shape[0] * jnp.sum((x - mu[None]) ** 2)


@functools.partial(jax.jit, static_argnames=("k",))
def cut_cost(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """Balanced k-cut cost on the complete sq-Euclidean graph (Section 5.5).

    cut = total pairwise - within pairwise; minimizing it == maximizing W(C).
    """
    return total_pairwise(x) - objective_pairwise(x, labels, k)


def balance_ok(labels, k: int, n: int | None = None) -> bool:
    """Check constraint (2): all sizes in {floor(N/K), ceil(N/K)}."""
    import numpy as np

    labels = np.asarray(labels)
    n = n or labels.shape[0]
    counts = np.bincount(labels, minlength=k)
    return counts.min() >= n // k and counts.max() <= -(-n // k)
