"""Anticlustering objectives and diversity statistics (paper Section 2 + Fact 1).

Two equivalent forms (Fact 1):
  pairwise form :  W(C) = sum_k sum_{i<i' in C_k} ||x_i - x_i'||^2
  centroid form :  W(C) = sum_k n_k * sum_{i in C_k} ||x_i - mu_k||^2

The paper's experiment tables report ``ofv`` as the *centroid* sum
``sum_k sum_{i in C_k} ||x_i - mu_k||^2`` (without the n_k factor, see
Section 5.3) while Table 11 (balanced k-cut) uses the pairwise W(C).  We
expose all three plus the per-cluster diversity stats (sd / range) used in
Tables 6 and 10.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k",))
def cluster_sizes(labels: jnp.ndarray, k: int) -> jnp.ndarray:
    return jnp.zeros((k,), jnp.int32).at[labels].add(1)


@functools.partial(jax.jit, static_argnames=("k",))
def centroids(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """(k, d) cluster centroids via segment-sum."""
    sums = jax.ops.segment_sum(x, labels, num_segments=k)
    counts = cluster_sizes(labels, k)
    return sums / jnp.maximum(counts, 1)[:, None].astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("k",))
def diversity_per_cluster(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """d_k = sum_{i in C_k} ||x_i - mu_k||^2  (the paper's per-cluster diversity)."""
    mu = centroids(x, labels, k)
    sq = jnp.sum((x - mu[labels]) ** 2, axis=-1)
    return jax.ops.segment_sum(sq, labels, num_segments=k)


@functools.partial(jax.jit, static_argnames=("k",))
def objective_centroid(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """sum_k sum_{i in C_k} ||x_i - mu_k||^2  -- the tables' ``ofv``."""
    return jnp.sum(diversity_per_cluster(x, labels, k))


@functools.partial(jax.jit, static_argnames=("k",))
def objective_pairwise(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """W(C) = sum_k n_k * d_k  (Fact 1) -- Table 11's W(C)."""
    div = diversity_per_cluster(x, labels, k)
    counts = cluster_sizes(labels, k).astype(x.dtype)
    return jnp.sum(counts * div)


@functools.partial(jax.jit, static_argnames=("k",))
def diversity_stats(x: jnp.ndarray, labels: jnp.ndarray, k: int):
    """(sd, range) of the k per-cluster diversities (Tables 6/10)."""
    div = diversity_per_cluster(x, labels, k)
    return jnp.std(div), jnp.max(div) - jnp.min(div)


@jax.jit
def total_pairwise(x: jnp.ndarray) -> jnp.ndarray:
    """sum_{i<i'} ||x_i - x_i'||^2 = N * sum_i ||x_i - mu||^2 (Fact 1, K=1)."""
    mu = jnp.mean(x, axis=0)
    return x.shape[0] * jnp.sum((x - mu[None]) ** 2)


@functools.partial(jax.jit, static_argnames=("k",))
def cut_cost(x: jnp.ndarray, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """Balanced k-cut cost on the complete sq-Euclidean graph (Section 5.5).

    cut = total pairwise - within pairwise; minimizing it == maximizing W(C).
    """
    return total_pairwise(x) - objective_pairwise(x, labels, k)


# Rows per certificate chunk: bounds the (chunk, k) distance block the
# dual-slack pass materializes, so the certificate stays O(chunk * k) live
# memory at million-row / large-k scale (mirroring aba_stream's budget).
_CERT_BLOCK = 1 << 22


@functools.partial(jax.jit, static_argnames=("k",))
def _cert_chunk(xc, lc, wc, mu, mu_sq, p, k):
    """One row chunk of the certificate: (G,) ofv part, (G,) slack part.

    ``xc`` (G, C, D) rows, ``lc`` (G, C) labels, ``wc`` (G, C) 0/1 validity,
    ``mu`` (G, k, D) centroids with ``mu_sq`` (G, k) their squared norms,
    ``p`` (G, k) prices.  cost(i, c) = ||x_i - mu_c||^2 expanded so the only
    (C, k)-sized intermediate is the one matmul product.
    """
    xn = jnp.sum(xc * xc, axis=-1)                           # (G, C)
    d2 = xn[..., None] - 2.0 * jnp.einsum(
        "gcd,gkd->gck", xc, mu) + mu_sq[:, None, :]          # (G, C, k)
    v = jnp.take_along_axis(d2, lc[..., None], axis=2)[..., 0]
    slack = jnp.max(d2 - p[:, None, :], axis=-1)
    return jnp.sum(v * wc, axis=1), jnp.sum(slack * wc, axis=1)


def dual_certificate(x, labels, prices, k: int, *, valid_mask=None):
    """LP-dual optimality-gap certificate from the auction's carried duals.

    Returns ``(dual_bound, gap)``.  For the realized partition's cluster
    sizes ``n_c`` and centroids ``mu_c``, every balanced reassignment ``z``
    of the rows to clusters-with-capacities satisfies (weak duality of the
    transportation relaxation, for ANY price vector ``p``)::

        sum_i cost(i, z_i) <= sum_c n_c p_c + sum_i max_c (cost(i, c) - p_c)

    with ``cost(i, c) = ||x_i - mu_c||^2``, so ``dual_bound`` upper-bounds
    the best achievable ``ofv`` (= :func:`objective_centroid`) over
    reassignments *at these centroids*, and ``gap = (dual_bound - ofv) /
    max(ofv, eps) >= 0`` certifies how far the achieved assignment is from
    assignment-optimal -- near-zero means provably converged.  The bound is
    valid for any prices; the auction's carried duals make it near-tight
    (zero prices degrade it to the trivial row-max bound), following the
    dual-bound idea of "Strong bounds for large-scale Minimum Sum-of-Squares
    Clustering" (PAPERS.md).  It is a *local* certificate: reassigning rows
    also moves the centroids, so it bounds the assignment step, not the
    global anticlustering optimum.

    Accepts flat ``(n, d)`` / ``(k,)`` prices and stacked ``(G, M, D)`` /
    ``(G, k)`` inputs (then returns (G,) arrays); ``valid_mask`` excludes
    padding rows.  Rows stream through fixed-size chunks so peak live
    memory stays O(chunk * k) at any n.
    """
    x = jnp.asarray(x, jnp.float32)
    squeeze = x.ndim == 2
    if squeeze:
        x, labels = x[None], jnp.asarray(labels)[None]
        prices = jnp.asarray(prices, jnp.float32)[None]
        valid_mask = None if valid_mask is None else \
            jnp.asarray(valid_mask)[None]
    labels = jnp.asarray(labels, jnp.int32)
    prices = jnp.asarray(prices, jnp.float32)
    G, M, D = x.shape
    w = (jnp.ones((G, M), jnp.float32) if valid_mask is None
         else jnp.asarray(valid_mask).astype(jnp.float32))
    seg = jnp.where(w > 0, labels + k * jnp.arange(
        G, dtype=jnp.int32)[:, None], G * k)
    sizes = jax.ops.segment_sum(
        w.reshape(-1), seg.reshape(-1), num_segments=G * k + 1
    )[:G * k].reshape(G, k)
    sums = jax.ops.segment_sum(
        (x * w[..., None]).reshape(-1, D), seg.reshape(-1),
        num_segments=G * k + 1)[:G * k].reshape(G, k, D)
    mu = sums / jnp.maximum(sizes, 1.0)[..., None]
    mu_sq = jnp.sum(mu * mu, axis=-1)

    chunk = max(1, min(M, _CERT_BLOCK // max(k, 1)))
    ofv = jnp.zeros((G,), jnp.float32)
    slack = jnp.zeros((G,), jnp.float32)
    for s in range(0, M, chunk):
        e = min(s + chunk, M)
        xc, lc, wc = x[:, s:e], labels[:, s:e], w[:, s:e]
        if e - s < chunk:  # pad the tail so every chunk shares one trace
            pad = chunk - (e - s)
            xc = jnp.concatenate([xc, jnp.zeros((G, pad, D), xc.dtype)], 1)
            lc = jnp.concatenate([lc, jnp.zeros((G, pad), lc.dtype)], 1)
            wc = jnp.concatenate([wc, jnp.zeros((G, pad), wc.dtype)], 1)
        v, sl = _cert_chunk(xc, lc, wc, mu, mu_sq, prices, k)
        ofv = ofv + v
        slack = slack + sl
    bound = jnp.sum(sizes * prices, axis=-1) + slack
    gap = (bound - ofv) / jnp.maximum(ofv, 1e-12)
    if squeeze:
        return bound[0], gap[0]
    return bound, gap


def balance_ok(labels, k: int, n: int | None = None) -> bool:
    """Check constraint (2): all sizes in {floor(N/K), ceil(N/K)}."""
    import numpy as np

    labels = np.asarray(labels)
    n = n or labels.shape[0]
    counts = np.bincount(labels, minlength=k)
    return counts.min() >= n // k and counts.max() <= -(-n // k)
