"""K-plus feature augmentation (Papenberg 2024; paper Section 3.3).

The paper notes that squared-Euclidean anticlustering only equalizes
anticluster *means*; to also balance higher moments, augment each feature
with its centered powers ((x - mean)^2 for variance, ^3 for skew, ...).  ABA
then balances the moments automatically because they are just extra columns.
The paper flags the D-blowup as a cost concern -- with ABA's O(N K D / K)
cost-matrix work the blowup is linear and cheap, which we verify in
tests/test_kplus.py (variance spread across anticlusters drops by an order
of magnitude at ~2x runtime).
"""

from __future__ import annotations

import numpy as np


def kplus_augment(x: np.ndarray, moments: int = 2) -> np.ndarray:
    """Append standardized centered-moment features for moments 2..moments."""
    assert moments >= 1
    x = np.asarray(x, np.float64)
    cols = [x]
    centered = x - x.mean(axis=0, keepdims=True)
    for m in range(2, moments + 1):
        f = centered ** m
        std = f.std(axis=0, keepdims=True)
        cols.append((f - f.mean(axis=0, keepdims=True))
                    / np.maximum(std, 1e-12))
    return np.concatenate(cols, axis=1).astype(np.float32)


def moment_spread(x: np.ndarray, labels: np.ndarray, k: int,
                  moment: int = 2) -> float:
    """Max-min spread of the per-anticluster feature moments (avg over D)."""
    x = np.asarray(x, np.float64)
    vals = []
    for g in range(k):
        xg = x[labels == g]
        mu = xg.mean(axis=0)
        vals.append(((xg - mu) ** moment).mean(axis=0))
    vals = np.stack(vals)
    return float((vals.max(axis=0) - vals.min(axis=0)).mean())
