"""Linear assignment solvers for ABA.

The paper's reference implementation uses LAPJV (Jonker-Volgenant), a
branch-heavy serial algorithm that maps poorly onto TPU vector/matrix units.
Following the paper's own future-work pointer (Bertsekas' auction algorithm,
Section 6), we implement a fully vectorized **Jacobi auction** with
epsilon-scaling: every round is a dense top-2 reduction over the cost matrix
plus scatter-max bidding -- VPU/MXU friendly, `vmap`-able, and usable inside
`lax.scan`/`shard_map`.

All solvers MAXIMIZE total cost (anticlustering assigns batches to the
*farthest* centroids).

Solvers
-------
- ``auction_solve``      eps-optimal, jit/vmap-safe, the production solver.
- ``greedy_solve``       O(n^3) vectorized greedy, cheap lower-quality option.
- ``scipy_solve``        exact Hungarian via scipy (host-side oracle/tests).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_NEG = -1e30  # sentinel "minus infinity" that survives f32 arithmetic


class AuctionConfig(NamedTuple):
    """Epsilon-scaling schedule for the auction solver.

    eps runs ``n_phases`` geometric steps from ``span/eps_start_div`` down to
    ``span/(eps_end_mul * n)``.  An eps-optimal assignment is within
    ``n * eps`` of the optimum; the default schedule gives objective parity
    with the Hungarian oracle to ~1e-6 relative on random instances.

    ``fixed_rounds > 0`` replaces the convergence while-loop with a
    fixed-length scan (the round update is a no-op at the converged fixed
    point).  Used by the dry-run so XLA knows every trip count, and on TPU it
    avoids host round-trips for the loop predicate.
    """

    n_phases: int = 4
    eps_start_div: float = 8.0
    eps_end_mul: float = 4.0
    max_rounds: int = 0  # 0 -> auto (50 * n + 1000)
    fixed_rounds: int = 0


def _top2_masked(values: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Row-wise (best value, best index, second value) of a (m, n) matrix."""
    j1 = jnp.argmax(values, axis=1)
    v1 = jnp.take_along_axis(values, j1[:, None], axis=1)[:, 0]
    masked = values.at[jnp.arange(values.shape[0]), j1].set(_NEG)
    v2 = jnp.max(masked, axis=1)
    return v1, j1, v2


def _auction_phase(cost: jnp.ndarray, prices: jnp.ndarray, eps: jnp.ndarray,
                   max_rounds: int, fixed_rounds: int = 0,
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One epsilon phase of Jacobi forward auction (maximization).

    Returns (row_to_col, prices).  All rows start unassigned; prices persist
    across phases (standard eps-scaling).
    """
    n = cost.shape[0]
    rows = jnp.arange(n, dtype=jnp.int32)
    cols = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        assign, _owner, _prices, it = state
        return jnp.logical_and(jnp.any(assign < 0), it < max_rounds)

    def body(state):
        assign, owner, prices, it = state
        unassigned = assign < 0
        values = cost - prices[None, :]
        v1, j1, v2 = _top2_masked(values)
        # Bid: raise the price of the favourite object past the point of
        # indifference with the runner-up, plus eps.
        bids = cost[rows, j1] - v2 + eps
        bid_val = jnp.where(unassigned, bids, _NEG)
        # Per-object best bid (scatter-max) and winning row (min row index
        # among rows achieving the best bid -- deterministic tie-break).
        best = jnp.full((n,), _NEG, cost.dtype).at[j1].max(bid_val)
        is_best = jnp.logical_and(unassigned, bid_val >= best[j1])
        cand = jnp.where(is_best, rows, n)
        winner = jnp.full((n,), n, jnp.int32).at[j1].min(cand)
        got_bid = winner < n
        # Rows whose object was just outbid become unassigned.  (They were
        # assigned, hence did not bid, hence cannot also be winners.)
        safe_assign = jnp.where(assign >= 0, assign, 0)
        lost = jnp.logical_and(assign >= 0,
                               jnp.logical_and(got_bid[safe_assign],
                                               winner[safe_assign] != rows))
        assign = jnp.where(lost, -1, assign)
        # Winners take their objects at the winning bid.
        winner_safe = jnp.where(got_bid, winner, n)
        assign = assign.at[winner_safe].set(cols, mode="drop")
        owner = jnp.where(got_bid, winner, owner)
        prices = jnp.where(got_bid, best, prices)
        return assign, owner, prices, it + 1

    assign0 = jnp.full((n,), -1, jnp.int32)
    owner0 = jnp.full((n,), -1, jnp.int32)
    if fixed_rounds:
        # converged state is a fixed point of body (no bids -> no updates)
        def scan_body(state, _):
            return body(state), None
        (assign, _owner, prices, _it), _ = jax.lax.scan(
            scan_body, (assign0, owner0, prices, jnp.int32(0)),
            None, length=fixed_rounds)
    else:
        assign, _owner, prices, _it = jax.lax.while_loop(
            cond, body, (assign0, owner0, prices, jnp.int32(0)))
    return assign, prices


@functools.partial(jax.jit, static_argnames=("config",))
def auction_solve(cost: jnp.ndarray,
                  config: AuctionConfig = AuctionConfig()) -> jnp.ndarray:
    """eps-optimal max-cost assignment of a square (n, n) cost matrix.

    Returns ``row_to_col`` (n,) int32.  Safe under ``vmap`` and inside
    ``lax.scan``.  Rectangular problems must be padded by the caller
    (constant-cost dummy rows are neutral: any column suits them).
    """
    cost = cost.astype(jnp.float32)
    n = cost.shape[0]
    if n == 1:
        return jnp.zeros((1,), jnp.int32)
    finite = jnp.where(cost <= _NEG / 2, 0.0, cost)
    span = jnp.maximum(jnp.max(finite) - jnp.min(finite), 1e-6)
    eps_hi = span / config.eps_start_div
    eps_lo = span / (config.eps_end_mul * n)
    n_phases = max(int(config.n_phases), 1)
    if n_phases > 1:
        ratio = (eps_lo / eps_hi) ** (1.0 / (n_phases - 1))
        eps_sched = eps_hi * ratio ** jnp.arange(n_phases, dtype=jnp.float32)
    else:
        eps_sched = eps_lo[None]
    max_rounds = config.max_rounds or (50 * n + 1000)

    def phase(prices, eps):
        assign, prices = _auction_phase(cost, prices, eps, max_rounds,
                                        config.fixed_rounds)
        return prices, assign

    prices0 = jnp.zeros((n,), jnp.float32)
    _prices, assigns = jax.lax.scan(phase, prices0, eps_sched)
    assign = assigns[-1]
    # Safety net: if the round cap was hit, columns may be unassigned; patch
    # them greedily so the result is always a permutation.
    return _repair_permutation(assign)


def _repair_permutation(assign: jnp.ndarray) -> jnp.ndarray:
    """Fill any ``-1`` rows with the unused columns (order-preserving)."""
    n = assign.shape[0]
    used = jnp.zeros((n,), jnp.bool_).at[jnp.where(assign >= 0, assign, 0)].set(
        assign >= 0)
    free_cols = jnp.argsort(used, stable=True)  # unused columns first
    need = assign < 0
    slot = jnp.cumsum(need) - 1  # index into free_cols per needy row
    return jnp.where(need, free_cols[slot], assign).astype(jnp.int32)


@jax.jit
def greedy_solve(cost: jnp.ndarray) -> jnp.ndarray:
    """Vectorized global-greedy max assignment: n rounds of masked argmax."""
    n = cost.shape[0]
    def body(_i, state):
        c, assign = state
        flat = jnp.argmax(c)
        r, col = flat // n, flat % n
        assign = assign.at[r].set(col.astype(jnp.int32))
        c = c.at[r, :].set(_NEG).at[:, col].set(_NEG)
        return c, assign
    _c, assign = jax.lax.fori_loop(
        0, n, body, (cost.astype(jnp.float32), jnp.full((n,), -1, jnp.int32)))
    return assign


def scipy_solve(cost: np.ndarray) -> np.ndarray:
    """Exact max-cost assignment (Hungarian) -- host-side oracle."""
    from scipy.optimize import linear_sum_assignment

    rows, cols = linear_sum_assignment(np.asarray(cost), maximize=True)
    out = np.empty(cost.shape[0], dtype=np.int32)
    out[rows] = cols
    return out


def assignment_value(cost: np.ndarray, row_to_col: np.ndarray) -> float:
    return float(np.asarray(cost)[np.arange(len(row_to_col)), row_to_col].sum())
