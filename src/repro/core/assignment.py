"""Linear assignment solvers for ABA.

The paper's reference implementation uses LAPJV (Jonker-Volgenant), a
branch-heavy serial algorithm that maps poorly onto TPU vector/matrix units.
Following the paper's own future-work pointer (Bertsekas' auction algorithm,
Section 6), we implement a fully vectorized **Jacobi auction** with
epsilon-scaling: every round is a dense top-2 reduction over the cost matrix
plus scatter-max bidding -- VPU/MXU friendly, `vmap`-able, and usable inside
`lax.scan`/`shard_map`.

The engine is **batched-native**: a ``(B, k, k)`` cost stack is solved in one
fused round loop with per-instance convergence masking (a converged instance
is a fixed point of the round update), not a ``vmap`` over scalar solves.
Hierarchical ABA feeds every level's padded group batch through this path as
a single solver call.

All solvers MAXIMIZE total cost (anticlustering assigns batches to the
*farthest* centroids).

Solvers
-------
- ``auction_solve``           eps-optimal, jit/vmap-safe, accepts (k, k) or a
                              stacked (B, k, k); the production solver.
- ``auction_solve_factored``  matrix-free auction on ``cost = -2 x.c^T +
                              ||c||^2``; the bidding top-2 streams through the
                              fused Pallas ``bid_top2`` kernel (TPU) so the
                              value matrix is never re-materialized per round.
- ``greedy_solve``            O(n^3) vectorized greedy, cheap lower-quality.
- ``scipy_solve``             exact Hungarian via scipy (host-side oracle).

The **solver registry** (``register_solver`` / ``get_solver``) is how the ABA
core finds its LAP backend: every entry is a :class:`Solver` whose ``solve``
accepts a ``(B, n, n)`` stack (or ``(n, n)``) plus an optional warm-start
``prices`` vector and returns ``(assignment, prices)``, maximizing total
cost, with an optional matrix-free ``factored`` path.  The price vector is
the auction's dual state: :class:`repro.anticluster.AnticlusterEngine`
carries it across repeated same-shape solves (``repartition``) so each epoch
warm-starts the epsilon-scaling schedule instead of re-discovering the
equilibrium from zero.  Price-less backends (greedy, Hungarian) pass the
incoming prices through unchanged.  ``auction``, ``auction_fused``,
``greedy`` and ``scipy`` are registered by default; benchmarks and users add
LAP backends with one ``register_solver`` call instead of editing the core.
Backends registered with the legacy price-less signature
``solve(cost, config)`` are wrapped in a pass-through shim (with a
``DeprecationWarning``) so third-party registrations keep working.
"""

from __future__ import annotations

import functools
import inspect
import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_NEG = -1e30  # sentinel "minus infinity" that survives f32 arithmetic

# Warm re-entry slack: the probe's max contested value gap overestimates the
# eps scale a warm solve must re-enter at, because only the few contested
# objects need price movement and the final phase's while-loop absorbs that
# in a handful of rounds.  Measured on the epoch bench (2048x16x8 CPU smoke):
# steady-state warm batches probe at 1.5-25x eps_lo yet the final phase alone
# converges faster than any added phase, so only gaps beyond this slack times
# the phase eps re-enter mid-schedule (prices carried across genuinely
# different problems probe at O(span), far past it).
_REENTRY_SLACK = 32.0


class AuctionConfig(NamedTuple):
    """Epsilon-scaling schedule for the auction solver.

    eps runs ``n_phases`` geometric steps from ``span/eps_start_div`` down to
    ``span/(eps_end_mul * n)``.  An eps-optimal assignment is within
    ``n * eps`` of the optimum; the default schedule gives objective parity
    with the Hungarian oracle to ~1e-6 relative on random instances.

    ``fixed_rounds > 0`` replaces the convergence while-loop with a
    fixed-length scan (the round update is a no-op at the converged fixed
    point).  Used by the dry-run so XLA knows every trip count, and on TPU it
    avoids host round-trips for the loop predicate.

    ``adaptive_reentry`` controls where a *warm-started* solve re-enters the
    schedule: ``True`` (default) measures the carried prices' dual
    infeasibility and runs every phase whose eps is at or below it (near-
    equilibrium prices still take only the final phase; drifted prices get
    the mid-schedule phases they actually need); ``False`` keeps the fixed
    legacy behaviour of always jumping straight to the final small-eps phase.
    Cold (all-zero-price) instances always run the full ramp either way.
    """

    n_phases: int = 4
    eps_start_div: float = 8.0
    eps_end_mul: float = 4.0
    max_rounds: int = 0  # 0 -> auto (50 * n + 1000)
    fixed_rounds: int = 0
    adaptive_reentry: bool = True


# ---------------------------------------------------------------------------
# Batched engine
# ---------------------------------------------------------------------------

def _top2_batched(values: jnp.ndarray):
    """Last-axis (best value, best index, second value) of a (..., n) array."""
    j1 = jnp.argmax(values, axis=-1).astype(jnp.int32)
    v1 = jnp.take_along_axis(values, j1[..., None], axis=-1)[..., 0]
    col = jax.lax.broadcasted_iota(jnp.int32, values.shape, values.ndim - 1)
    v2 = jnp.max(jnp.where(col == j1[..., None], _NEG, values), axis=-1)
    return v1, j1, v2


def _auction_phase(top2_fn, prices: jnp.ndarray, eps: jnp.ndarray,
                   max_rounds: int, fixed_rounds: int = 0,
                   skip: jnp.ndarray | None = None,
                   seed_top2=None,
                   return_rounds: bool = False,
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One epsilon phase of batched Jacobi forward auction (maximization).

    ``top2_fn(prices)`` returns the per-row ``(v1, j1, v2)`` of the reduced
    value matrix ``value[b, i, j] = cost[b, i, j] - prices[b, j]``, each
    (B, n) -- the *bidding round reduction*, pluggable so the dense path and
    the fused matrix-free kernel path share one engine.  Prices/eps are
    (B, n) / (B,).  Returns (row_to_col, prices).  All rows start unassigned;
    prices persist across phases (standard eps-scaling).  A fully assigned
    instance places no bids, so the round update is a no-op for it while the
    rest of the batch keeps iterating (per-instance convergence masking).

    ``skip`` ((B,) bool) marks instances that sit this phase out entirely:
    their rows start pre-assigned (identity), so by the masking above they
    never bid and their prices pass through untouched -- the warm-start path
    uses this to run only the phases at or below its measured re-entry eps
    per warm instance while cold instances in the same stack keep the full
    ramp.

    ``seed_top2`` optionally supplies the first round's ``(v1, j1, v2)``
    reduction, precomputed at the *incoming* prices -- the warm path's
    infeasibility probe is exactly that reduction, so threading it here
    makes the probe free (it becomes round one).  The values are what the
    round would compute itself, so results are unchanged.

    ``return_rounds=True`` additionally returns the phase's executed round
    count (the ``it`` counter the loop already carries) -- the solver
    telemetry source, free because the value exists either way.
    """
    B, n = prices.shape
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
    barange = jnp.arange(B)[:, None]

    def cond(state):
        assign, _owner, _prices, it = state
        return jnp.logical_and(jnp.any(assign < 0), it < max_rounds)

    def body_with(state, top2):
        assign, owner, prices, it = state
        unassigned = assign < 0
        v1, j1, v2 = top2
        # Bid: raise the price of the favourite object past the point of
        # indifference with the runner-up, plus eps.  Using the identity
        # cost[b, i, j1] = v1 + prices[b, j1] keeps the phase matrix-free.
        bids = v1 + jnp.take_along_axis(prices, j1, axis=1) - v2 + eps[:, None]
        bid_val = jnp.where(unassigned, bids, _NEG)
        # Per-object best bid (scatter-max) and winning row (min row index
        # among rows achieving the best bid -- deterministic tie-break).
        best = jnp.full((B, n), _NEG, bids.dtype).at[barange, j1].max(bid_val)
        is_best = jnp.logical_and(
            unassigned, bid_val >= jnp.take_along_axis(best, j1, axis=1))
        cand = jnp.where(is_best, rows, n)
        winner = jnp.full((B, n), n, jnp.int32).at[barange, j1].min(cand)
        got_bid = winner < n
        # Rows whose object was just outbid become unassigned.  (They were
        # assigned, hence did not bid, hence cannot also be winners.)
        safe_assign = jnp.where(assign >= 0, assign, 0)
        lost = jnp.logical_and(
            assign >= 0,
            jnp.logical_and(
                jnp.take_along_axis(got_bid, safe_assign, axis=1),
                jnp.take_along_axis(winner, safe_assign, axis=1) != rows))
        assign = jnp.where(lost, -1, assign)
        # Winners take their objects at the winning bid.
        winner_safe = jnp.where(got_bid, winner, n)
        assign = assign.at[barange, winner_safe].set(cols, mode="drop")
        owner = jnp.where(got_bid, winner, owner)
        prices = jnp.where(got_bid, best, prices)
        return assign, owner, prices, it + 1

    def body(state):
        return body_with(state, top2_fn(state[2]))

    assign0 = jnp.full((B, n), -1, jnp.int32)
    if skip is not None:
        # pre-assigned identity: no bids, a fixed point of the round update
        assign0 = jnp.where(skip[:, None], cols, assign0)
    owner0 = jnp.full((B, n), -1, jnp.int32)
    state0 = (assign0, owner0, prices, jnp.int32(0))
    rounds = fixed_rounds
    if seed_top2 is not None:
        # round one, with the caller's precomputed reduction (same values
        # the round would compute; identical results, one reduction saved)
        state0 = body_with(state0, seed_top2)
        rounds = max(fixed_rounds - 1, 0)
    if fixed_rounds:
        # converged state is a fixed point of body (no bids -> no updates)
        def scan_body(state, _):
            return body(state), None
        (assign, _owner, prices, it), _ = jax.lax.scan(
            scan_body, state0, None, length=rounds)
    else:
        assign, _owner, prices, it = jax.lax.while_loop(cond, body, state0)
    if return_rounds:
        return assign, prices, it
    return assign, prices


def _eps_schedule(span: jnp.ndarray, n: int, config: AuctionConfig):
    """(B,) span -> (n_phases, B) geometric epsilon schedule."""
    eps_hi = span / config.eps_start_div
    eps_lo = span / (config.eps_end_mul * n)
    n_phases = max(int(config.n_phases), 1)
    if n_phases > 1:
        ratio = (eps_lo / eps_hi) ** (1.0 / (n_phases - 1))
        steps = jnp.arange(n_phases, dtype=jnp.float32)
        return eps_hi[None, :] * ratio[None, :] ** steps[:, None]
    return eps_lo[None, :]


def _run_phases(top2_fn, eps_sched: jnp.ndarray, n: int,
                config: AuctionConfig,
                prices0: jnp.ndarray | None = None,
                return_stats: bool = False,
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run the eps-scaling schedule; returns (assignment, final prices).

    ``return_stats=True`` appends a solver telemetry pytree -- the numbers
    the loops already compute, surfaced instead of discarded, so the stats
    path costs no extra traced work beyond stacking them:

    * ``rounds``  (n_phases,) int32 -- executed bidding rounds per phase
      (the phase while-loop's own counter; a skipped warm phase exits on
      its first predicate check and reports 0/1 rounds).
    * ``eps``     (n_phases, B)     -- the geometric epsilon schedule.
    * ``warm``    (B,) bool         -- instances that entered with carried
      (nonzero) prices.
    * ``reentry`` (B,) float32      -- the measured re-entry epsilon per
      instance (-inf on the legacy fixed shortcut; 0 on the cold path).
    * ``skipped`` (n_phases, B) bool -- which phases each instance sat out.

    ``prices0`` warm-starts the solve ((B, n); ``None`` or all-zeros is the
    cold path).  Epsilon scaling exists to tame the round count from
    *uninformed* prices -- its early large-eps phases actively re-scramble
    an already-converged price equilibrium (measured: warm-starting the full
    schedule saves nothing, and re-running *every* phase at the final small
    epsilon costs almost as much as the cold ramp).  So the price-carrying
    path skips phases **per instance**: an instance whose incoming prices
    are all zero (the engine's cold-start sentinel) runs the full ramp,
    bit-identical to ``prices0=None``; an instance with carried (nonzero)
    duals *re-enters the schedule adaptively* -- one probe bidding round at
    the carried prices measures its dual infeasibility (the largest
    value gap a row stands to lose where several rows contest the same
    object; zero at a clean equilibrium), and the instance sits out every
    phase whose eps exceeds that measured infeasibility (rows start
    pre-assigned, placing no bids -- the same per-instance convergence
    masking that lets converged instances free-wheel).  Near-equilibrium
    prices therefore still run only the final small-eps phase (the fixed
    legacy shortcut, ``config.adaptive_reentry=False`` forces it), while
    prices carried across drifted data re-enter mid-schedule and converge
    in far fewer rounds than the final phase alone would need from that
    distance.  The last phase always runs, so the ``n * eps_lo`` optimality
    bound of the full schedule is kept either way.  The final prices are the
    dual state a repeated caller threads into its next same-shape solve.
    """
    B = eps_sched.shape[1]
    n_phases = eps_sched.shape[0]
    max_rounds = config.max_rounds or (50 * n + 1000)

    def phase(prices, eps):
        assign, prices = _auction_phase(top2_fn, prices, eps, max_rounds,
                                        config.fixed_rounds)
        return prices, assign

    def phase_stats(prices, eps):
        assign, prices, it = _auction_phase(top2_fn, prices, eps, max_rounds,
                                            config.fixed_rounds,
                                            return_rounds=True)
        return prices, (assign, it)

    if prices0 is None:
        if not return_stats:
            prices, assigns = jax.lax.scan(
                phase, jnp.zeros((B, n), jnp.float32), eps_sched)
            # Safety net: if the round cap was hit, columns may be
            # unassigned; patch them greedily so the result is always a
            # permutation.
            return _repair_permutation(assigns[-1]), prices
        prices, (assigns, rounds) = jax.lax.scan(
            phase_stats, jnp.zeros((B, n), jnp.float32), eps_sched)
        stats = {"rounds": rounds.astype(jnp.int32), "eps": eps_sched,
                 "warm": jnp.zeros((B,), bool),
                 "reentry": jnp.zeros((B,), jnp.float32),
                 "skipped": jnp.zeros((n_phases, B), bool)}
        return _repair_permutation(assigns[-1]), prices, stats

    prices0 = prices0.astype(jnp.float32)
    is_warm = jnp.any(prices0 != 0.0, axis=1)          # (B,) per instance
    is_last = jnp.arange(n_phases) == n_phases - 1
    if config.adaptive_reentry:
        # Probe reduction at the carried prices: rows whose favourite object
        # is contested (demanded by >1 rows) must either outbid or fall back
        # to their runner-up, so max contested (v1 - v2) tracks the price
        # movement still needed -- the eps scale worth re-entering at.  The
        # reduction is fed back in as the first executed round's top-2
        # (seed_top2), so the probe costs nothing extra.
        probe = top2_fn(prices0)
        v1, j1, v2 = probe
        barange = jnp.arange(B)[:, None]
        demand = jnp.zeros((B, n), jnp.float32).at[barange, j1].add(1.0)
        contested = jnp.take_along_axis(demand, j1, axis=1) > 1.0
        infeas = jnp.max(jnp.where(contested, v1 - v2, 0.0), axis=1)
        reentry = jnp.clip(infeas / _REENTRY_SLACK, eps_sched[-1],
                           eps_sched[0])
    else:
        # legacy fixed shortcut: warm instances skip all but the last phase
        probe = None
        reentry = jnp.full((B,), -jnp.inf)

    def phase_p(prices, inp):
        eps, last = inp
        skip = jnp.logical_and(
            is_warm,
            jnp.logical_and(jnp.logical_not(last), eps > reentry))
        assign, prices = _auction_phase(
            top2_fn, prices, eps, max_rounds, config.fixed_rounds,
            skip=skip)
        return prices, assign

    def phase_p_stats(prices, inp):
        eps, last = inp
        skip = jnp.logical_and(
            is_warm,
            jnp.logical_and(jnp.logical_not(last), eps > reentry))
        assign, prices, it = _auction_phase(
            top2_fn, prices, eps, max_rounds, config.fixed_rounds,
            skip=skip, return_rounds=True)
        return prices, (assign, it)

    # Phase 1 unrolled so it can consume the probe reduction (every instance
    # still holds the incoming prices there); the remaining phases scan.  A
    # skipped phase's while-loop exits on its first predicate check (all
    # rows pre-assigned), so the steady-state engine case -- every instance
    # warm at equilibrium, only the final phase live -- costs the same as
    # the old jump-straight-to-the-last-phase shortcut (measured slightly
    # less: a branchless scan of empty phases beats a lax.cond dispatch).
    skip0 = jnp.logical_and(
        is_warm, jnp.logical_and(jnp.logical_not(is_last[0]),
                                 eps_sched[0] > reentry))
    if not return_stats:
        assign, prices = _auction_phase(
            top2_fn, prices0, eps_sched[0], max_rounds, config.fixed_rounds,
            skip=skip0, seed_top2=probe)
        if n_phases > 1:
            prices, assigns = jax.lax.scan(
                phase_p, prices, (eps_sched[1:], is_last[1:]))
            assign = assigns[-1]
        return _repair_permutation(assign), prices
    assign, prices, it0 = _auction_phase(
        top2_fn, prices0, eps_sched[0], max_rounds, config.fixed_rounds,
        skip=skip0, seed_top2=probe, return_rounds=True)
    rounds = it0[None]
    if n_phases > 1:
        prices, (assigns, its) = jax.lax.scan(
            phase_p_stats, prices, (eps_sched[1:], is_last[1:]))
        assign = assigns[-1]
        rounds = jnp.concatenate([rounds, its])
    skipped = jnp.logical_and(
        is_warm[None, :],
        jnp.logical_and(jnp.logical_not(is_last)[:, None],
                        eps_sched > reentry[None, :]))
    stats = {"rounds": rounds.astype(jnp.int32), "eps": eps_sched,
             "warm": is_warm, "reentry": reentry, "skipped": skipped}
    return _repair_permutation(assign), prices, stats


def _solve_stack(cost: jnp.ndarray, config: AuctionConfig,
                 prices0: jnp.ndarray | None = None,
                 return_stats: bool = False,
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B, n, n) -> ((B, n) assignment, (B, n) prices); the dense engine."""
    B, n, _ = cost.shape
    finite = jnp.where(cost <= _NEG / 2, 0.0, cost)
    span = jnp.maximum(jnp.max(finite, axis=(1, 2))
                       - jnp.min(finite, axis=(1, 2)), 1e-6)

    def top2_fn(prices):
        return _top2_batched(cost - prices[:, None, :])

    return _run_phases(top2_fn, _eps_schedule(span, n, config), n, config,
                       prices0, return_stats=return_stats)


def _zero_stats(B: int, config: AuctionConfig) -> dict:
    """The telemetry pytree for solves that run no phases (n == 1)."""
    p = max(int(config.n_phases), 1)
    return {"rounds": jnp.zeros((p,), jnp.int32),
            "eps": jnp.zeros((p, B), jnp.float32),
            "warm": jnp.zeros((B,), bool),
            "reentry": jnp.zeros((B,), jnp.float32),
            "skipped": jnp.zeros((p, B), bool)}


def _squeeze_stats(stats: dict) -> dict:
    """Drop the B axis for single-instance (squeezed) solves."""
    return {"rounds": stats["rounds"], "eps": stats["eps"][:, 0],
            "warm": stats["warm"][0], "reentry": stats["reentry"][0],
            "skipped": stats["skipped"][:, 0]}


@functools.partial(jax.jit,
                   static_argnames=("config", "return_prices",
                                    "return_stats"))
def auction_solve(cost: jnp.ndarray,
                  config: AuctionConfig = AuctionConfig(), *,
                  prices: jnp.ndarray | None = None,
                  return_prices: bool = False,
                  return_stats: bool = False) -> jnp.ndarray:
    """eps-optimal max-cost assignment; single matrix or batched stack.

    ``(n, n)`` input returns ``row_to_col`` (n,) int32; a stacked
    ``(B, n, n)`` input returns (B, n), solved in ONE fused round loop with
    per-instance convergence masking -- instance b's result is identical to
    ``auction_solve(cost[b])``.  Safe under ``vmap`` and inside ``lax.scan``.
    Rectangular problems must be padded by the caller (constant-cost dummy
    rows are neutral: any column suits them; a padded instance converges
    early and free-wheels at its fixed point while the rest finish).

    ``prices`` warm-starts the epsilon schedule from a carried price vector
    ((n,) / (B, n); ``None`` = zeros, the cold path -- bit-identical to the
    pre-warm-start behaviour).  ``return_prices=True`` additionally returns
    the final prices (the shape of the assignment), which is what the
    registry's price-carrying ``solve`` signature exposes.
    ``return_stats=True`` returns ``(assignment, prices, stats)`` where
    ``stats`` is the solver telemetry pytree of :func:`_run_phases` (rounds
    per eps phase, the eps schedule, warm re-entry decisions); the
    assignment and prices are identical to the plain call.
    """
    cost = cost.astype(jnp.float32)
    in_shape = cost.shape
    if cost.ndim not in (2, 3):
        raise ValueError(f"cost must be (n, n) or (B, n, n), got {in_shape}")
    squeeze = cost.ndim == 2
    if squeeze:
        cost = cost[None]
        prices = None if prices is None else prices[None]
    B, n, n2 = cost.shape
    if n != n2:
        raise ValueError(f"cost must be square, got {in_shape}")
    stats = None
    if n == 1:
        out = jnp.zeros((B, 1), jnp.int32)
        p_out = (jnp.zeros((B, 1), jnp.float32) if prices is None
                 else prices.astype(jnp.float32))
        if return_stats:
            stats = _zero_stats(B, config)
    elif return_stats:
        out, p_out, stats = _solve_stack(cost, config, prices,
                                         return_stats=True)
    else:
        out, p_out = _solve_stack(cost, config, prices)
    if return_stats:
        if squeeze:
            return out[0], p_out[0], _squeeze_stats(stats)
        return out, p_out, stats
    if return_prices:
        return (out[0], p_out[0]) if squeeze else (out, p_out)
    return out[0] if squeeze else out


@functools.partial(jax.jit,
                   static_argnames=("config", "force", "return_prices",
                                    "return_stats"))
def auction_solve_factored(x: jnp.ndarray, c: jnp.ndarray, *,
                           is_real: jnp.ndarray | None = None,
                           config: AuctionConfig = AuctionConfig(),
                           force: str | None = None,
                           prices: jnp.ndarray | None = None,
                           return_prices: bool = False,
                           return_stats: bool = False) -> jnp.ndarray:
    """Matrix-free auction on ``cost[i, j] = -2 x_i . c_j + ||c_j||^2``.

    This is the ABA batch-to-centroid LAP with the row-constant ``||x||^2``
    dropped.  Each bidding round's top-2 reduction runs through the fused
    ``kernels.ops.bid_top2`` dispatch -- the Pallas kernel on TPU (column
    tiles streamed through VMEM, O(k) output), ``interpret=True`` on CPU --
    so the (k, k) value matrix is never re-materialized per round.  Only the
    one-off span estimate for the eps schedule touches a dense product.

    A first-class registry backend (``"auction_fused"``'s ``factored``
    path): it takes a single ``(k, d) x (k, d)`` problem OR the ABA core's
    stacked ``(G, k, d) x (G, k, d)`` batch (per-group centroids; the
    bidding reduction vmaps the kernel, which on TPU is one extra grid dim).
    ``is_real`` marks dummy rows whose cost is the neutral constant 0,
    matching the dense masked path in :func:`repro.core.aba.aba_core`.
    ``prices`` / ``return_prices`` carry the auction's dual state exactly as
    in :func:`auction_solve` (warm start in, final prices out);
    ``return_stats`` appends the solver telemetry pytree, also as there.
    Returns ``row_to_col`` (k,) / (G, k) int32.
    """
    from repro.kernels.ops import bid_top2

    if x.shape[-2] != c.shape[-2]:
        raise ValueError(
            f"LAP must be square: {x.shape[-2]} != {c.shape[-2]}")
    squeeze = x.ndim == 2
    if squeeze:
        x, c = x[None], c[None]
        is_real = None if is_real is None else is_real[None]
        prices = None if prices is None else prices[None]
    G, n, _ = x.shape
    if n == 1:
        out = jnp.zeros((G, 1), jnp.int32)
        if return_prices or return_stats:
            p_out = (jnp.zeros((G, 1), jnp.float32) if prices is None
                     else prices.astype(jnp.float32))
            if return_stats:
                stats = _zero_stats(G, config)
                if squeeze:
                    return out[0], p_out[0], _squeeze_stats(stats)
                return out, p_out, stats
            return (out[0], p_out[0]) if squeeze else (out, p_out)
        return out[0] if squeeze else out
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    cn = jnp.sum(c * c, axis=-1)  # (G, n)

    # one-off span for the eps schedule (fused per-row extrema: the max is
    # bid_top2 at zero prices; the min is the max of the negated values,
    # reachable with prices = 2 * ||c||^2 and x -> -x)
    hi_v1, _, _ = bid_top2(x, c, jnp.zeros((G, n), jnp.float32), force=force)
    lo_v1, _, _ = bid_top2(-x, c, 2.0 * cn, force=force)
    if is_real is not None:
        any_dummy = jnp.any(~is_real, axis=1)
        hi = jnp.max(jnp.where(is_real, hi_v1, _NEG), axis=1)
        lo = -jnp.max(jnp.where(is_real, lo_v1, _NEG), axis=1)
        hi = jnp.where(any_dummy, jnp.maximum(hi, 0.0), hi)
        lo = jnp.where(any_dummy, jnp.minimum(lo, 0.0), lo)
    else:
        hi = jnp.max(hi_v1, axis=1)
        lo = -jnp.max(lo_v1, axis=1)
    span = jnp.maximum(hi - lo, 1e-6)  # (G,)

    def top2_fn(prices):
        v1, j1, v2 = bid_top2(x, c, prices, force=force)
        if is_real is not None:
            # dummy rows see the constant-0 cost row: value = -prices, the
            # same vector for every dummy row of a group, so the per-group
            # (G,) top-2 broadcasts across the row axis
            dv1, dj1, dv2 = _top2_batched(-prices)
            v1 = jnp.where(is_real, v1, dv1[:, None])
            j1 = jnp.where(is_real, j1, dj1[:, None])
            v2 = jnp.where(is_real, v2, dv2[:, None])
        return v1, j1, v2

    if return_stats:
        out, p_out, stats = _run_phases(
            top2_fn, _eps_schedule(span, n, config), n, config, prices,
            return_stats=True)
        if squeeze:
            return out[0], p_out[0], _squeeze_stats(stats)
        return out, p_out, stats
    out, p_out = _run_phases(top2_fn, _eps_schedule(span, n, config), n,
                             config, prices)
    if return_prices:
        return (out[0], p_out[0]) if squeeze else (out, p_out)
    return out[0] if squeeze else out


def solve_restricted_slots(cost: jnp.ndarray, mandatory: jnp.ndarray, *,
                           solver: str = "auction",
                           config: AuctionConfig = AuctionConfig(),
                           prices: jnp.ndarray | None = None,
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Frozen-price restricted assignment of m arriving rows over T slots.

    The delta-update subsystem's LAP (``repro.incremental``): ``cost`` is
    the (m, T) value of placing each arriving row into each open capacity
    slot (m <= T), ``mandatory`` ((T,) bool) marks slots that MUST take a
    real row (clusters below the balance floor).  The problem is squared
    with ``T - m`` neutral dummy rows (constant cost 0, the ``aba_core``
    dummy convention) barred from mandatory slots by a penalty scaled to
    the real cost span: with ``pen = -(4 * span + 1)`` an exchange argument
    against the schedule's ``T * eps_lo <= span_solver / 4`` optimality
    slack shows an eps-optimal assignment never takes a penalized pair when
    a feasible completion exists (the categorical ``_MASK_COST = -1e9``
    would instead blow up the span-derived epsilon schedule and with it the
    placement quality).

    ``prices`` ((T,) float32) warm-starts the solve from carried per-slot
    duals; nonzero prices engage ``_run_phases``' adaptive re-entry probe,
    so near-equilibrium slots sit out all but the final epsilon phase while
    contested slots re-enter mid-schedule -- "all other prices frozen" falls
    out of the probe rather than an explicit mask.

    Returns ``(slots, slot_prices)``: each real row's slot ((m,) int32) and
    the final duals ((T,) float32).  Jit/scan-safe for auction backends.
    """
    cost = jnp.asarray(cost, jnp.float32)
    if cost.ndim != 2:
        raise ValueError(f"cost must be (m, T), got {cost.shape}")
    m, T = cost.shape
    if m > T:
        raise ValueError(f"m={m} arriving rows exceed T={T} open slots")
    solver_obj = get_solver(solver)
    if m == T:
        square = cost
    else:
        # dummy rows see cost 0, so the span must cover 0 like the factored
        # path's any_dummy branch does
        hi = jnp.maximum(jnp.max(cost), 0.0)
        lo = jnp.minimum(jnp.min(cost), 0.0)
        pen = -(4.0 * jnp.maximum(hi - lo, 1e-6) + 1.0)
        dummy = jnp.where(jnp.asarray(mandatory, jnp.bool_), pen, 0.0)
        square = jnp.concatenate(
            [cost, jnp.broadcast_to(dummy, (T - m, T))], axis=0)
    assign, p_out = solver_obj.solve(square, config, prices)
    return assign[:m].astype(jnp.int32), p_out


def _repair_permutation(assign: jnp.ndarray) -> jnp.ndarray:
    """Fill any ``-1`` rows with the unused columns (order-preserving)."""
    B, n = assign.shape
    barange = jnp.arange(B)[:, None]
    safe = jnp.where(assign >= 0, assign, 0)
    used = jnp.zeros((B, n), jnp.int32).at[barange, safe].add(
        (assign >= 0).astype(jnp.int32)) > 0
    free_cols = jnp.argsort(used, axis=1, stable=True)  # unused columns first
    need = assign < 0
    slot = jnp.cumsum(need, axis=1) - 1  # index into free_cols per needy row
    fill = jnp.take_along_axis(free_cols, jnp.maximum(slot, 0), axis=1)
    return jnp.where(need, fill, assign).astype(jnp.int32)


@jax.jit
def greedy_solve(cost: jnp.ndarray) -> jnp.ndarray:
    """Vectorized global-greedy max assignment: n rounds of masked argmax."""
    n = cost.shape[0]
    def body(_i, state):
        c, assign = state
        flat = jnp.argmax(c)
        r, col = flat // n, flat % n
        assign = assign.at[r].set(col.astype(jnp.int32))
        c = c.at[r, :].set(_NEG).at[:, col].set(_NEG)
        return c, assign
    _c, assign = jax.lax.fori_loop(
        0, n, body, (cost.astype(jnp.float32), jnp.full((n,), -1, jnp.int32)))
    return assign


def scipy_solve(cost: np.ndarray) -> np.ndarray:
    """Exact max-cost assignment (Hungarian) -- host-side oracle."""
    from scipy.optimize import linear_sum_assignment

    rows, cols = linear_sum_assignment(np.asarray(cost), maximize=True)
    out = np.empty(cost.shape[0], dtype=np.int32)
    out[rows] = cols
    return out


def assignment_value(cost: np.ndarray, row_to_col: np.ndarray) -> float:
    return float(np.asarray(cost)[np.arange(len(row_to_col)), row_to_col].sum())


# ---------------------------------------------------------------------------
# Solver registry
# ---------------------------------------------------------------------------

class Solver(NamedTuple):
    """A registered LAP backend for the ABA core.

    ``solve(cost, config, prices=None)`` takes a ``(B, n, n)`` stack (or a
    single ``(n, n)`` matrix) plus an optional warm-start price vector
    ((B, n) / (n,)) and returns ``(row_to_col, prices)`` of shapes
    ``(B, n)`` / ``(n,)``, MAXIMIZING total cost; it must be jit/scan-safe
    (host solvers wrap themselves in ``jax.pure_callback``).  ``prices=None``
    is the cold start; backends without a price concept (greedy, Hungarian)
    return the incoming prices unchanged (zeros when cold) so the engine's
    state threading stays a no-op for them.  ``factored`` is the optional
    matrix-free path ``factored(x, c, is_real=..., config=..., prices=...)``
    -> ``(row_to_col, prices)`` used by the ABA core whenever the cost
    factors as ``-2 x.c^T + ||c||^2`` (no categorical mask); it must accept
    both ``(n, d)`` and the core's stacked ``(G, n, d)`` inputs (the
    fused-kernel auction does).

    Backends registered with the legacy price-less signature
    ``solve(cost, config) -> row_to_col`` are auto-wrapped in a pass-through
    shim by :func:`register_solver` (with a ``DeprecationWarning``).

    ``host_callback`` marks backends that round-trip to the host from inside
    the traced computation (``jax.pure_callback`` -- e.g. the scipy
    Hungarian).  Such a solve occupies the host thread while it "runs on
    device", so dispatching it asynchronously buys no overlap and the
    engine's non-blocking path (``AnticlusterEngine.dispatch_repartition``,
    ``repro.train.pipeline``) refuses it up front and falls back to the
    synchronous route.

    ``solve_stats`` / ``factored_stats`` are the optional telemetry twins:
    the same signatures as ``solve`` / ``factored`` but returning
    ``(row_to_col, prices, stats)`` where ``stats`` is the auction telemetry
    pytree (see ``_run_phases``).  Backends without internals worth
    reporting leave them ``None`` and the engine's
    ``AnticlusterSpec(telemetry=True)`` path statically degrades to no
    telemetry for them -- never a traced-op cost on anyone's default path.
    """

    solve: Callable
    factored: Callable | None = None
    host_callback: bool = False
    solve_stats: Callable | None = None
    factored_stats: Callable | None = None


_REGISTRY: dict[str, Solver] = {}


def _accepts_prices(fn: Callable) -> bool:
    try:
        return "prices" in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # C callables etc.: assume legacy
        return False


def _prices_or_zeros(shape_src: jnp.ndarray, prices):
    """Pass-through prices for price-less backends ((..., n) from (..., n, n))."""
    if prices is not None:
        return jnp.asarray(prices, jnp.float32)
    return jnp.zeros(shape_src.shape[:-1], jnp.float32)


def _legacy_solve_shim(solve: Callable) -> Callable:
    @functools.wraps(solve)
    def shim(cost, config=AuctionConfig(), prices=None):
        return solve(cost, config), _prices_or_zeros(cost, prices)
    return shim


def _legacy_factored_shim(factored: Callable) -> Callable:
    @functools.wraps(factored)
    def shim(x, c, *, is_real=None, config=AuctionConfig(), prices=None):
        out = factored(x, c, is_real=is_real, config=config)
        if prices is None:
            prices = jnp.zeros(c.shape[:-1], jnp.float32)  # (G, n) / (n,)
        return out, jnp.asarray(prices, jnp.float32)
    return shim


def register_solver(name: str, solve: Callable, *,
                    factored: Callable | None = None,
                    host_callback: bool = False,
                    solve_stats: Callable | None = None,
                    factored_stats: Callable | None = None,
                    overwrite: bool = False) -> Solver:
    """Register a LAP backend under ``name`` (see :class:`Solver`).

    The canonical signature is price-carrying:
    ``solve(cost, config, prices=None) -> (row_to_col, prices)``.  A solver
    whose signature has no ``prices`` parameter is treated as the legacy
    price-less form ``solve(cost, config) -> row_to_col`` and wrapped in a
    pass-through shim (incoming prices are returned unchanged, zeros when
    cold) with a ``DeprecationWarning`` -- warm starts are a no-op for such
    backends but everything else keeps working.

    Pass ``host_callback=True`` for backends that execute on the host via
    ``jax.pure_callback``: the engine's async dispatch path refuses them
    (there is nothing to overlap with -- the "device" work IS host work).

    The ABA core resolves ``name`` at *trace* time (solver names are static
    jit arguments), so ``overwrite=True`` does not reach already-compiled
    core traces -- re-registering an existing name changes future traces
    only.  Register under a fresh name (or clear jax caches) when comparing
    backends within one process.
    """
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"solver {name!r} already registered "
                         f"(pass overwrite=True to replace it)")
    if not _accepts_prices(solve):
        warnings.warn(
            f"solver {name!r} uses the deprecated price-less signature "
            "solve(cost, config); wrapping it in a pass-through shim. "
            "Migrate to solve(cost, config, prices=None) -> "
            "(assignment, prices) to participate in warm starts.",
            DeprecationWarning, stacklevel=2)
        solve = _legacy_solve_shim(solve)
    if factored is not None and not _accepts_prices(factored):
        warnings.warn(
            f"solver {name!r}: factored path uses the deprecated price-less "
            "signature; wrapping it in a pass-through shim.",
            DeprecationWarning, stacklevel=2)
        factored = _legacy_factored_shim(factored)
    solver = Solver(solve=solve, factored=factored,
                    host_callback=host_callback,
                    solve_stats=solve_stats,
                    factored_stats=factored_stats)
    _REGISTRY[name] = solver
    return solver


def get_solver(name: str) -> Solver:
    if name not in _REGISTRY:
        raise KeyError(f"unknown solver {name!r}; registered: "
                       f"{available_solvers()}")
    return _REGISTRY[name]


def available_solvers() -> tuple[str, ...]:
    """Sorted names of every registered LAP backend.

    Every listed backend satisfies the price-carrying :class:`Solver`
    contract (legacy registrations are shimmed at registration time), so
    each is usable both by one-shot ``anticluster()`` calls and as the
    warm-started engine inside ``AnticlusterEngine.repartition``.
    """
    return tuple(sorted(_REGISTRY))


def _auction_solve_p(cost: jnp.ndarray,
                     config: AuctionConfig = AuctionConfig(),
                     prices: jnp.ndarray | None = None):
    """Registry entry: price-carrying wrapper over ``auction_solve``."""
    return auction_solve(cost, config, prices=prices, return_prices=True)


def _auction_factored_p(x: jnp.ndarray, c: jnp.ndarray, *,
                        is_real: jnp.ndarray | None = None,
                        config: AuctionConfig = AuctionConfig(),
                        prices: jnp.ndarray | None = None):
    """Registry entry: price-carrying wrapper over the matrix-free auction."""
    return auction_solve_factored(x, c, is_real=is_real, config=config,
                                  prices=prices, return_prices=True)


def _auction_solve_stats(cost: jnp.ndarray,
                         config: AuctionConfig = AuctionConfig(),
                         prices: jnp.ndarray | None = None):
    """Registry entry: telemetry twin of ``_auction_solve_p``."""
    return auction_solve(cost, config, prices=prices, return_stats=True)


def _auction_factored_stats(x: jnp.ndarray, c: jnp.ndarray, *,
                            is_real: jnp.ndarray | None = None,
                            config: AuctionConfig = AuctionConfig(),
                            prices: jnp.ndarray | None = None):
    """Registry entry: telemetry twin of ``_auction_factored_p``."""
    return auction_solve_factored(x, c, is_real=is_real, config=config,
                                  prices=prices, return_stats=True)


def _greedy_stack(cost: jnp.ndarray,
                  config: AuctionConfig = AuctionConfig(),
                  prices: jnp.ndarray | None = None):
    del config  # greedy has no tuning knobs
    if cost.ndim == 3:
        out = jax.vmap(greedy_solve)(cost)
    else:
        out = greedy_solve(cost)
    return out, _prices_or_zeros(cost, prices)  # price-less: pass-through


def _scipy_host_stack(cost: np.ndarray) -> np.ndarray:
    return np.stack([scipy_solve(c) for c in cost])


def scipy_solve_jax(cost: jnp.ndarray,
                    config: AuctionConfig = AuctionConfig(),
                    prices: jnp.ndarray | None = None):
    """Exact Hungarian as a jit/scan-safe backend via ``pure_callback``.

    The oracle solver, usable anywhere ``auction_solve`` is: each stack
    instance round-trips to the host, so it is CPU-speed by construction --
    the registry entry exists for exactness checks and tiny problems.
    Hungarian has no dual price state worth carrying, so the warm-start
    ``prices`` are passed through unchanged (zeros when cold).
    """
    del config
    cost = jnp.asarray(cost, jnp.float32)
    squeeze = cost.ndim == 2
    stack = cost[None] if squeeze else cost
    out = jax.pure_callback(
        _scipy_host_stack,
        jax.ShapeDtypeStruct(stack.shape[:2], jnp.int32),
        stack, vmap_method="sequential")
    return out[0] if squeeze else out, _prices_or_zeros(cost, prices)


register_solver("auction", _auction_solve_p,
                solve_stats=_auction_solve_stats)
register_solver("auction_fused", _auction_solve_p,
                factored=_auction_factored_p,
                solve_stats=_auction_solve_stats,
                factored_stats=_auction_factored_stats)
register_solver("greedy", _greedy_stack)
register_solver("scipy", scipy_solve_jax, host_callback=True)
