"""Hierarchical decomposition of ABA (paper Section 4.4).

K = K_1 x ... x K_L.  Level 1 runs ABA on the full data with K_1; every later
level runs ABA **independently on each group** -- the paper exploits this with
threads, we exploit it with the batched-native auction engine (one
``aba_core`` call whose scan steps solve the whole (G, k, k) LAP stack in a
single fused loop) on one device, and ``shard_map`` (``repro.core.sharded``)
across the mesh.

Groups whose sizes differ by one (Proposition 1) are gathered into a fixed
(G, M) index matrix with a validity mask, so every level is a single batched
ABA call with static shapes.  Total complexity O(N * sum_l K_l^2), minimized
by balanced factors (Lemma 1) -- ``default_plan`` picks them.

Categorical constraints (Section 4.3) compose across levels: each level
stratifies within its groups, and since ``ceil(ceil(n/a)/b) == ceil(n/(ab))``
(and likewise for floor), the final K = prod(plan) anticlusters satisfy the
global constraint (5) exactly.  ``hierarchical_core`` therefore threads
``categories`` through every level.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.aba import aba_core, aba_stream
from repro.core.assignment import AuctionConfig


def _plan_search(k: int, max_k: int) -> tuple[int, ...] | None:
    """Balanced factorization with backtracking; None if none is admissible."""
    if k <= max_k:
        return (k,)
    n_levels = 2
    while k ** (1.0 / n_levels) > max_k:
        n_levels += 1
    target = k ** (1.0 / n_levels)
    cands, seen = [], set()
    for d in range(2, math.isqrt(k) + 1):
        for cand in (d, k // d):
            if k % cand == 0 and 2 <= cand <= max_k and cand not in seen:
                seen.add(cand)
                cands.append(cand)
    # stable sort keeps the legacy greedy preference among equidistant factors
    cands.sort(key=lambda c: abs(c - target))
    for cand in cands:
        rest = _plan_search(k // cand, max_k)
        if rest is not None:
            return (cand,) + rest
    return None


def default_plan(k: int, max_k: int = 512) -> tuple[int, ...]:
    """Balanced factorization of k per Lemma 1, every factor <= ``max_k``.

    Mirrors the paper's Table 5/7 settings, e.g. 5000 -> (50, 100) style
    splits.  The ``max_k`` contract is strict: when no factorization of k
    into factors <= max_k exists (k prime, or k with an unavoidable prime
    factor > max_k), a ValueError is raised instead of silently scheduling
    the full k x k auction the hierarchy was supposed to prevent.
    """
    if k < 1:
        raise ValueError(f"k={k} must be >= 1")
    plan = _plan_search(k, max_k)
    if plan is None:
        raise ValueError(
            f"k={k} has no factorization with every factor <= max_k={max_k} "
            f"(prime factor too large); raise max_k or choose an adjacent k")
    return plan


def _regroup(glabels: jnp.ndarray, valid: jnp.ndarray, n_groups: int,
             m_new: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Build the (n_groups, m_new) padded index matrix from global labels."""
    n = glabels.shape[0]
    key = jnp.where(valid, glabels, n_groups)  # padding sorts last
    order = jnp.argsort(key, stable=True)
    counts = jnp.zeros((n_groups,), jnp.int32).at[
        jnp.where(valid, glabels, 0)].add(valid.astype(jnp.int32))
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = starts[:, None] + jnp.arange(m_new, dtype=jnp.int32)[None, :]
    new_valid = jnp.arange(m_new, dtype=jnp.int32)[None, :] < counts[:, None]
    idx = jnp.where(new_valid, order[jnp.minimum(pos, n - 1)], n)
    return idx, new_valid


@functools.partial(
    jax.jit,
    static_argnames=("plan", "variant", "n_categories", "n_fair_codes",
                     "solver", "auction_config", "batched", "chunk_size",
                     "return_state"),
)
def hierarchical_core(
    x: jnp.ndarray,
    plan: tuple[int, ...],
    *,
    variant: str = "auto",
    categories: jnp.ndarray | None = None,
    n_categories: int = 0,
    fair_codes: jnp.ndarray | None = None,
    n_fair_codes: int = 0,
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
    batched: bool = True,
    chunk_size: int | None = None,
    prices: tuple[jnp.ndarray, ...] | None = None,
    return_state: bool = False,
) -> jnp.ndarray:
    """ABA with L = len(plan) hierarchical levels; labels in [0, prod(plan)).

    Every level runs through the one rank-polymorphic ``aba_core``: level 1
    as the G=1 flat case (with the full variant/categorical machinery), each
    level >= 2 as ONE stacked call whose scan steps solve the whole
    (G, k_l, k_l) LAP stack in a single batched auction loop.
    ``batched=False`` keeps the legacy ``vmap`` over per-group G=1 cores (the
    two give identical labels -- the flag exists so benchmarks can measure
    the difference).  ``categories`` stratifies at every level (see module
    docstring for why the global constraint (5) still holds exactly).

    ``chunk_size`` streams **level 1** (the only level that sees all n rows
    at once) through ``repro.core.aba.aba_stream`` -- categories and
    ``fair_codes`` included (the chunked rank-in-category pass keeps level-1
    labels bit-identical to the dense level at chunk >= n); levels >= 2 work
    on n/K_1-row group stacks and stay on the dense batched core.

    ``fair_codes`` / ``n_fair_codes`` thread the multi-attribute fairness
    quota codes (see ``aba_core``) through every level; like categories, the
    per-level ceil quotas compose (ceil-of-ceil), so each attribute's global
    cap holds level by level.  Requires the ``batched=True`` level engine.

    ``prices`` warm-starts every level's auction from a per-level carried
    price tuple (level l has shape ``(prod(plan[:l]), plan[l])``, level 1 is
    ``(1, plan[0])`` -- see :func:`plan_price_shapes`); ``None`` is the
    bit-identical cold path.  ``return_state`` additionally returns
    ``{"prices": per-level tuple, "mu": (d,) level-1 centrality moment}``.
    State threading requires the ``batched=True`` level engine (the legacy
    vmap path exists only for benchmarking).
    """
    n = x.shape[0]
    k_total = math.prod(plan)
    if k_total > n:
        raise ValueError(f"prod(plan)={k_total} > n={n}")
    if (not batched) and (return_state or prices is not None):
        raise NotImplementedError(
            "price/state threading requires batched=True levels")
    if (not batched) and fair_codes is not None:
        raise NotImplementedError(
            "fair_codes requires the batched=True level engine")
    kw = dict(variant=variant, solver=solver, auction_config=auction_config,
              n_categories=n_categories)

    xf = x.astype(jnp.float32)
    x_ext = jnp.concatenate([xf, jnp.zeros((1, xf.shape[1]), jnp.float32)])
    if categories is not None:
        cat_i = categories.astype(jnp.int32)
        cat_ext = jnp.concatenate([cat_i, jnp.zeros((1,), jnp.int32)])
    if fair_codes is not None:
        codes_i = fair_codes.astype(jnp.int32)
        codes_ext = jnp.concatenate(
            [codes_i, jnp.zeros((1, codes_i.shape[-1]), jnp.int32)])

    p_levels = []
    p_in = (lambda i: None) if prices is None else (lambda i: prices[i])
    if chunk_size is not None:
        glabels, st1 = aba_stream(
            xf, plan[0], chunk_size, variant=variant,
            categories=None if categories is None else cat_i,
            n_categories=n_categories, fair_codes=fair_codes,
            n_fair_codes=n_fair_codes, solver=solver,
            auction_config=auction_config, prices=p_in(0), return_state=True)
        mu1 = st1["mu"]
    else:
        glabels, st1 = aba_core(
            xf[None], plan[0],
            categories=None if categories is None else cat_i[None],
            fair_codes=None if fair_codes is None else codes_i[None],
            n_fair_codes=n_fair_codes,
            prices=p_in(0), return_state=True, **kw)
        glabels = glabels[0]
        mu1 = st1["mu"][0]
    p_levels.append(st1["prices"])
    n_groups = plan[0]
    m = -(-n // n_groups)  # static upper bound on group size

    for li, k_l in enumerate(plan[1:], start=1):
        idx, valid = _regroup(glabels, jnp.ones((n,), jnp.bool_), n_groups, m)
        xg = x_ext[jnp.minimum(idx, n)]  # (G, M, D)
        cg = None if categories is None else cat_ext[jnp.minimum(idx, n)]
        fg = None if fair_codes is None else codes_ext[jnp.minimum(idx, n)]
        if batched:
            sub, st_l = aba_core(xg, k_l, valid, variant="base",
                                 categories=cg, n_categories=n_categories,
                                 fair_codes=fg, n_fair_codes=n_fair_codes,
                                 solver=solver,
                                 auction_config=auction_config,
                                 prices=p_in(li), return_state=True)
            p_levels.append(st_l["prices"])
        elif cg is None:
            sub = jax.vmap(
                lambda xx, vm: aba_core(xx[None], k_l, vm[None], **kw)[0]
            )(xg, valid)
        else:
            sub = jax.vmap(
                lambda xx, vm, cc: aba_core(
                    xx[None], k_l, vm[None], categories=cc[None], **kw)[0]
            )(xg, valid, cg)
        new_global = (jnp.arange(n_groups, dtype=jnp.int32)[:, None] * k_l + sub)
        glabels = jnp.zeros((n + 1,), jnp.int32).at[
            jnp.minimum(idx.reshape(-1), n)
        ].set(jnp.where(valid, new_global, 0).reshape(-1), mode="drop")[:n]
        n_groups *= k_l
        m = -(-m // k_l)
    if return_state:
        return glabels, {"prices": tuple(p_levels), "mu": mu1}
    return glabels


def plan_price_shapes(plan: tuple[int, ...]) -> tuple[tuple[int, int], ...]:
    """Per-level warm-start price shapes for ``hierarchical_core``.

    Level 1 solves the full data as one G=1 stack -> ``(1, plan[0])``;
    level l solves one LAP stack per group of the previous levels ->
    ``(prod(plan[:l-1]), plan[l-1])`` in 1-based level terms.
    """
    shapes, groups = [], 1
    for k_l in plan:
        shapes.append((groups, k_l))
        groups *= k_l
    return tuple(shapes)


# ---------------------------------------------------------------------------
# Deprecated shims (exact-parity wrappers over hierarchical_core)
# ---------------------------------------------------------------------------

def hierarchical_aba(
    x: jnp.ndarray,
    plan: tuple[int, ...],
    *,
    variant: str = "auto",
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
    batched: bool = True,
) -> jnp.ndarray:
    """Deprecated: use ``repro.anticluster.anticluster`` with ``plan=...``."""
    from repro.core.aba import _deprecated
    _deprecated("hierarchical_aba",
                "repro.anticluster.anticluster(x, spec) with spec.plan")
    return hierarchical_core(x, plan, variant=variant, solver=solver,
                             auction_config=auction_config, batched=batched)


def aba_auto(x, k: int, *, max_k: int = 512, batched: bool = True,
             variant: str = "auto", categories: jnp.ndarray | None = None,
             n_categories: int = 0, solver: str = "auction",
             auction_config: AuctionConfig = AuctionConfig()):
    """Deprecated: use ``repro.anticluster.anticluster`` (plan="auto")."""
    from repro.core.aba import _deprecated
    _deprecated("aba_auto",
                'repro.anticluster.anticluster(x, spec) with plan="auto"')
    plan = default_plan(k, max_k=max_k)
    kw = dict(variant=variant, n_categories=n_categories, solver=solver,
              auction_config=auction_config)
    if len(plan) == 1:
        return aba_core(
            x[None], k,
            categories=None if categories is None else categories[None],
            **kw)[0]
    return hierarchical_core(x, plan, categories=categories, batched=batched,
                             **kw)
