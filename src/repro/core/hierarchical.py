"""Hierarchical decomposition of ABA (paper Section 4.4).

K = K_1 x ... x K_L.  Level 1 runs ABA on the full data with K_1; every later
level runs ABA **independently on each group** -- the paper exploits this with
threads, we exploit it with the batched-native auction engine (one
``aba_batched`` call whose scan steps solve the whole (G, k, k) LAP stack in
a single fused loop) on one device, and ``shard_map`` (``repro.core.sharded``)
across the mesh.

Groups whose sizes differ by one (Proposition 1) are gathered into a fixed
(G, M) index matrix with a validity mask, so every level is a single batched
ABA call with static shapes.  Total complexity O(N * sum_l K_l^2), minimized
by balanced factors (Lemma 1) -- ``default_plan`` picks them.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.aba import aba, aba_batched
from repro.core.assignment import AuctionConfig


def default_plan(k: int, max_k: int = 512) -> tuple[int, ...]:
    """Balanced factorization of k per Lemma 1 (each factor <= max_k).

    Mirrors the paper's Table 5/7 settings, e.g. 5000 -> (10, 500) style
    splits; prime k falls back to (k,).
    """
    if k <= max_k:
        return (k,)
    n_levels = 2
    while k ** (1.0 / n_levels) > max_k:
        n_levels += 1
    target = k ** (1.0 / n_levels)
    best = None
    for d in range(2, int(math.isqrt(k)) + 1):
        for cand in (d, k // d):
            if k % cand == 0 and cand <= max_k:
                if best is None or abs(cand - target) < abs(best - target):
                    best = cand
    if best is None:  # prime or no factor under max_k
        return (k,)
    return (best,) + default_plan(k // best, max_k)


def _regroup(glabels: jnp.ndarray, valid: jnp.ndarray, n_groups: int,
             m_new: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Build the (n_groups, m_new) padded index matrix from global labels."""
    n = glabels.shape[0]
    key = jnp.where(valid, glabels, n_groups)  # padding sorts last
    order = jnp.argsort(key, stable=True)
    counts = jnp.zeros((n_groups,), jnp.int32).at[
        jnp.where(valid, glabels, 0)].add(valid.astype(jnp.int32))
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = starts[:, None] + jnp.arange(m_new, dtype=jnp.int32)[None, :]
    new_valid = jnp.arange(m_new, dtype=jnp.int32)[None, :] < counts[:, None]
    idx = jnp.where(new_valid, order[jnp.minimum(pos, n - 1)], n)
    return idx, new_valid


@functools.partial(
    jax.jit,
    static_argnames=("plan", "variant", "solver", "auction_config", "batched"),
)
def hierarchical_aba(
    x: jnp.ndarray,
    plan: tuple[int, ...],
    *,
    variant: str = "auto",
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
    batched: bool = True,
) -> jnp.ndarray:
    """ABA with L = len(plan) hierarchical levels; returns labels in [0, prod(plan)).

    With ``batched=True`` (default) every level >= 2 is ONE ``aba_batched``
    call whose scan steps each solve the whole (G, k_l, k_l) LAP stack in a
    single batched auction loop; ``batched=False`` keeps the legacy ``vmap``
    over per-group scalar solves (the two give identical labels -- the flag
    exists so benchmarks can measure the difference).
    """
    n = x.shape[0]
    k_total = math.prod(plan)
    if k_total > n:
        raise ValueError(f"prod(plan)={k_total} > n={n}")
    kw = dict(variant=variant, solver=solver, auction_config=auction_config)

    xf = x.astype(jnp.float32)
    x_ext = jnp.concatenate([xf, jnp.zeros((1, xf.shape[1]), jnp.float32)])

    glabels = aba(xf, plan[0], **kw)
    n_groups = plan[0]
    m = -(-n // n_groups)  # static upper bound on group size

    for k_l in plan[1:]:
        idx, valid = _regroup(glabels, jnp.ones((n,), jnp.bool_), n_groups, m)
        xg = x_ext[jnp.minimum(idx, n)]  # (G, M, D)
        if batched:
            sub = aba_batched(xg, k_l, valid, solver=solver,
                              auction_config=auction_config)
        else:
            sub = jax.vmap(
                lambda xx, vm: aba(xx, k_l, valid_mask=vm, **kw))(xg, valid)
        new_global = (jnp.arange(n_groups, dtype=jnp.int32)[:, None] * k_l + sub)
        glabels = jnp.zeros((n + 1,), jnp.int32).at[
            jnp.minimum(idx.reshape(-1), n)
        ].set(jnp.where(valid, new_global, 0).reshape(-1), mode="drop")[:n]
        n_groups *= k_l
        m = -(-m // k_l)
    return glabels


def aba_auto(x, k: int, *, max_k: int = 512, batched: bool = True, **kw):
    """ABA with an automatically chosen hierarchical plan (paper Table 5)."""
    plan = default_plan(k, max_k=max_k)
    if len(plan) == 1:
        return aba(x, k, **kw)
    return hierarchical_aba(x, plan, batched=batched, **kw)
