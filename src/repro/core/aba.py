"""The Assignment-Based Anticlustering algorithm (paper Section 4).

JAX implementation notes
------------------------
* ONE rank-polymorphic masked core (:func:`aba_core`) carries every regime:
  it takes a ``(G, M, D)`` stack of padded subproblems and the flat case is
  simply the ``G = 1`` specialization.  The centrality sort, the Section
  4.2/4.3 rearrangements, the pad-to-full-batches step and the Algorithm 1
  scan therefore exist exactly once; ``aba`` / ``aba_batched`` are thin
  deprecated shims over it (use :func:`repro.anticluster.anticluster`).
* The batch loop (Algorithm 1) is a ``lax.scan`` carrying the anticluster
  centroids and per-cluster counts.  It is inherently sequential -- each LAP
  depends on the centroids updated by the previous batch -- so parallelism
  comes from (a) the dense vectorized work inside one step (cost matrix +
  auction rounds, batched across the G subproblems) and (b) the hierarchical
  decomposition (Section 4.4), which feeds group stacks through this same
  core.
* The LAP input drops the row-constant ``||x_j||^2`` term: adding a constant
  per row never changes the optimal assignment, so the cost matrix is just
  ``-2 x . mu^T + ||mu||^2`` -- one matmul (MXU) plus a bias.
* The LAP backend comes from the solver registry
  (:func:`repro.core.assignment.get_solver`); every backend solves the whole
  ``(G, k, k)`` stack per scan step in one call.
* The Section 4.2 interleave rearrangement is a *static* permutation of sorted
  positions (depends only on M, K) and is precomputed in numpy at trace time.
* The Section 4.3 categorical rearrangement depends on data; it is expressed
  as a single lexicographic sort key so it stays jit/vmap-compatible, and it
  is batched over the group axis (hierarchical levels keep stratifying).
* ``valid_mask`` supports padded subproblems (hierarchical level >= 2 gathers
  groups whose sizes differ by one into a fixed-shape batch).
"""

from __future__ import annotations

import functools
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import AuctionConfig, get_solver
from repro.kernels.ops import gather_rows

_MASK_COST = -1e9  # categorical upper-bound mask (paper 4.3)

Variant = Literal["auto", "base", "interleave"]


def _deprecated(old: str, new: str):
    warnings.warn(
        f"repro.core.{old} is deprecated; use {new} "
        "(labels are guaranteed identical)",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Static rearrangements
# ---------------------------------------------------------------------------

def interleave_permutation(n: int, k: int) -> np.ndarray:
    """Section 4.2 rearrangement of *positions* 0..n-1 of the sorted list.

    Splits the sorted list into k sublists (short ones first when k does not
    divide n) and round-robins through them; the n - floor(n/k)*k leftovers
    (one per long sublist, nearest the global centroid) go to the end.
    """
    q, r = divmod(n, k)
    if q == 0:
        return np.arange(n)
    n_short = k - r  # sublists of length q; the remaining r have length q+1
    lengths = np.array([q] * n_short + [q + 1] * r)
    starts = np.concatenate([[0], np.cumsum(lengths)[:-1]])
    rounds = starts[None, :] + np.arange(q)[:, None]  # (q, k) round-robin
    perm = rounds.reshape(-1)
    if r:
        leftovers = starts[n_short:] + q
        perm = np.concatenate([perm, leftovers])
    return perm.astype(np.int32)


def categorical_sort_order(categories: jnp.ndarray, rank_in_cat: jnp.ndarray,
                           cat_counts: jnp.ndarray, k: int) -> jnp.ndarray:
    """Section 4.3: lexicographic order by (incomplete, block, category, pos).

    All inputs carry a leading group axis: ``categories`` / ``rank_in_cat``
    are (G, M) in centrality-sorted order (``rank_in_cat`` is each object's
    0-based position among objects of its category), ``cat_counts`` is
    (G, n_categories).  The returned (G, M) permutation yields the rearranged
    list per group: full K-blocks alternate across categories by block index;
    incomplete tail blocks come last in the same alternating order.
    """
    block = rank_in_cat // k
    pos = rank_in_cat % k
    n_g = jnp.take_along_axis(cat_counts, categories, axis=1)
    incomplete = ((block + 1) * k > n_g).astype(jnp.int32)
    # lexsort: last key is primary; sorts each group row independently
    return jnp.lexsort((pos, categories, block, incomplete), axis=-1)


# ---------------------------------------------------------------------------
# The shared Algorithm-1 batch step
# ---------------------------------------------------------------------------

def _assign_batch(solver_obj, fused, auction_config, cents, counts,
                  cat_counts, xb, is_real, cb=None, ub=None, prices=None,
                  stats_fn=None):
    """One Algorithm-1 batch on a (G, k, ...) stack: solve the LAP against
    the current centroids and fold the assigned rows into the running
    moments.  The ONE copy of the batch update -- the dense core's scan and
    the streaming core's chunked scan both call it, which is what makes the
    ``chunk_size >= n`` parity guarantee hold bit-for-bit.

    ``cb`` carries each row's quota codes as a (G, k, A) stack -- A = 1 with
    plain ``categories`` (the code IS the category), A > 1 for multi-attribute
    fairness (one offset code per attribute into a shared ``ub`` axis).  A
    cluster is closed for a row when ANY of the row's codes is at its
    ``ub`` quota, which with A = 1 degenerates exactly to constraint (5).

    ``prices`` warm-starts the batch LAP from a carried (G, k) price vector
    (``None`` = zeros: the cold path, unchanged); the solver's final prices
    are returned so a stateful caller can carry them into its next run.

    ``stats_fn`` (the solver's registered telemetry twin, resolved by the
    caller) swaps the solve for its ``(assign, prices, stats)`` variant;
    the trailing return slot then carries the per-batch telemetry pytree
    (``None`` on the default path, which stays byte-identical).
    """
    garange = jnp.arange(cents.shape[0])[:, None]
    stats = None
    if fused:
        # matrix-free bidding: the (k, k) value matrix is never built;
        # each auction round is one fused bid_top2 kernel call.
        if stats_fn is not None:
            assign, p_out, stats = stats_fn(xb, cents, is_real=is_real,
                                            config=auction_config,
                                            prices=prices)
        else:
            assign, p_out = solver_obj.factored(xb, cents, is_real=is_real,
                                                config=auction_config,
                                                prices=prices)
    else:
        # reduced cost: row-constant ||x||^2 dropped (LAP-invariant)
        cost = (-2.0 * jnp.einsum("gid,gjd->gij", xb, cents)
                + jnp.sum(cents * cents, axis=-1)[:, None, :])
        cost = jnp.where(is_real[..., None], cost, 0.0)  # neutral dummies
        if ub is not None:
            # cnt[g, i, j, a] = cat_counts[g, j, cb[g, i, a]]: how many of
            # row i's code-a peers cluster j already holds
            cnt = jnp.take_along_axis(
                cat_counts[:, None], cb[:, :, None, :], axis=3)
            quota = jnp.take_along_axis(ub[:, None], cb, axis=2)
            full = jnp.any(cnt >= quota[:, :, None, :], axis=-1)
            cost = jnp.where(jnp.logical_and(full, is_real[..., None]),
                             _MASK_COST, cost)
        if stats_fn is not None:
            assign, p_out, stats = stats_fn(cost, auction_config, prices)
        else:
            assign, p_out = solver_obj.solve(cost, auction_config,
                                             prices)  # (G, k) batched
    # centroid running mean: mu_k += (x - mu_k) / new_count  (Algorithm 1)
    new_counts = counts.at[garange, assign].add(is_real.astype(jnp.int32))
    delta = xb - jnp.take_along_axis(cents, assign[..., None], axis=1)
    upd = jnp.zeros_like(cents).at[garange, assign].add(
        jnp.where(is_real[..., None], delta, 0.0))
    cents = cents + upd / jnp.maximum(
        new_counts, 1)[..., None].astype(jnp.float32)
    if ub is not None:
        cat_counts = cat_counts.at[
            garange[..., None], assign[..., None], cb].add(
            is_real[..., None].astype(jnp.int32))
    return cents, new_counts, cat_counts, assign, p_out, stats


# ---------------------------------------------------------------------------
# The rank-polymorphic masked core
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "variant", "n_categories", "n_fair_codes",
                     "solver", "auction_config", "return_state",
                     "telemetry"),
)
def aba_core(
    x: jnp.ndarray,
    k: int,
    valid_mask: jnp.ndarray | None = None,
    *,
    variant: Variant = "base",
    categories: jnp.ndarray | None = None,
    n_categories: int = 0,
    fair_codes: jnp.ndarray | None = None,
    n_fair_codes: int = 0,
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
    prices: jnp.ndarray | None = None,
    return_state: bool = False,
    telemetry: bool = False,
) -> jnp.ndarray:
    """Assignment-Based Anticlustering on a ``(G, M, D)`` stack of problems.

    This is THE implementation of Algorithm 1 + variants 4.2/4.3: the flat
    case is ``G = 1``, hierarchical levels and sharded shards pass their
    padded group stacks directly.  Each scan step solves the whole
    ``(G, k, k)`` LAP stack with ONE batched solver call.

    Args:
      x: (G, M, D) float features, groups padded to a common M.
      k: number of anticlusters per group (static).
      valid_mask: optional (G, M) bool; False rows are padding -- they never
        influence real rows, but their returned labels are arbitrary in
        [0, k): callers must mask them out.  ``None`` means all rows valid
        (required for the static interleave rearrangement).
      variant: "base", "interleave" (Section 4.2), or "auto" (interleave when
        anticlusters are small, M/k <= 8, matching the paper's guidance).
        Interleave needs the true row count to be static, so it is skipped
        when ``valid_mask`` is given.
      categories: optional (G, M) int32 in [0, n_categories) -- Section 4.3,
        applied independently per group (stratification composes across
        hierarchical levels).
      n_categories: static number of categories (required with categories).
      fair_codes: optional (G, M, A) int32 multi-attribute quota codes --
        the proportional-fairness generalization of constraint (5).  The
        rearrangement still follows ``categories`` (the front door passes
        the joint attribute cell there), but the quota upper bounds are
        enforced per *code*: each of a row's A codes indexes a shared
        ``n_fair_codes``-wide count axis (attributes occupy disjoint offset
        ranges) and a cluster is closed once any code hits
        ``ceil(count(code)/k)``.  ``None`` (with categories) is exactly the
        single-attribute case: codes = categories, A = 1, bit-identical to
        the pre-fairness behaviour.
      n_fair_codes: static total code count (required with fair_codes).
      solver: registry name (see ``repro.core.assignment.register_solver``);
        defaults: "auction" | "auction_fused" | "greedy" | "scipy".  A solver
        with a matrix-free ``factored`` path (e.g. "auction_fused", whose
        bidding top-2 streams through the Pallas ``bid_top2`` kernel) uses it
        for category-free problems at any G (the stacked bidding vmaps the
        kernel) and falls back to its dense ``solve`` when categories are in
        play (the categorical upper-bound mask cannot be factored).
      prices: optional (G, k) float32 warm-start prices: every batch LAP in
        this run starts its epsilon schedule from this carried vector
        instead of zeros.  ``None`` (or zeros) is the cold path and is
        bit-for-bit identical to the pre-warm-start behaviour -- the
        assignment is eps-optimal either way, warm prices only cut rounds.
      return_state: also return the run's carried state as a dict with
        ``"prices"`` ((G, k) final prices of the last batch, the warm start
        for a repeated same-shape run) and ``"mu"`` ((G, d) per-group
        centrality centroid, the running moment of the sort phase).
      telemetry: (requires ``return_state``) the state dict additionally
        carries ``"telemetry"``: the solver's per-batch stats pytree stacked
        over the scan (auction rounds per eps phase, eps schedule, warm
        re-entry decisions; leading axis ``n_batches - 1``), or ``None``
        when the resolved solve path registers no telemetry twin or no
        batch LAP runs (``n_batches == 1``).  The labels and prices are
        bit-identical to the ``telemetry=False`` call; the flag is static,
        so the default executable is untouched.

    Returns:
      (G, M) int32 labels in [0, k); with ``return_state`` a
      ``(labels, state)`` tuple.
    """
    G, M, D = x.shape
    if k > M:
        raise ValueError(f"k={k} > M={M}")
    if telemetry and not return_state:
        raise ValueError("telemetry=True requires return_state=True (the "
                         "stats pytree rides the state dict)")
    solver_obj = get_solver(solver)
    xf = x.astype(jnp.float32)
    garange = jnp.arange(G)[:, None]

    # --- per-group centrality sort (descending distance to centroid) -------
    if valid_mask is None:
        mu = jnp.mean(xf, axis=1)
        dist = jnp.sum((xf - mu[:, None, :]) ** 2, axis=-1)
    else:
        w = valid_mask.astype(jnp.float32)
        mu = jnp.sum(xf * w[..., None], axis=1) / jnp.maximum(
            jnp.sum(w, axis=1), 1.0)[:, None]
        dist = jnp.where(valid_mask,
                         jnp.sum((xf - mu[:, None, :]) ** 2, axis=-1),
                         -jnp.inf)  # padding sorts to the end
    order = jnp.argsort(-dist, axis=1, stable=True).astype(jnp.int32)

    # --- rearrangement ------------------------------------------------------
    use_interleave = variant == "interleave" or (
        variant == "auto" and M // k <= 8)
    if categories is not None:
        if n_categories <= 0:
            raise ValueError("n_categories must be set with categories")
        cat_i = categories.astype(jnp.int32)
        cat_sorted = jnp.take_along_axis(cat_i, order, axis=1)
        if valid_mask is not None:
            # padding gets a virtual category that sorts last
            cat_sorted = jnp.where(
                jnp.take_along_axis(valid_mask, order, axis=1),
                cat_sorted, n_categories - 1)
        onehot = jax.nn.one_hot(cat_sorted, n_categories, dtype=jnp.int32)
        rank_in_cat = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=1) - onehot,
            cat_sorted[..., None], axis=2)[..., 0]
        cat_counts = jnp.sum(onehot, axis=1)
        order = jnp.take_along_axis(
            order, categorical_sort_order(cat_sorted, rank_in_cat,
                                          cat_counts, k), axis=1)
    elif use_interleave and valid_mask is None:
        order = order[:, jnp.asarray(interleave_permutation(M, k))]
    # (interleave + valid_mask: the true row count is dynamic, so the static
    #  rearrangement is unavailable; fall back to base order.)

    # --- pad to full batches -------------------------------------------------
    n_batches = -(-M // k)
    pad = n_batches * k - M
    order_p = (jnp.concatenate([order, jnp.full((G, pad), M, jnp.int32)], 1)
               if pad else order)
    real = order_p < M
    if valid_mask is not None:
        vm_ext = jnp.concatenate([valid_mask, jnp.zeros((G, 1), jnp.bool_)], 1)
        real = jnp.logical_and(
            real, jnp.take_along_axis(vm_ext, jnp.minimum(order_p, M), axis=1))
    batches = order_p.reshape(G, n_batches, k)
    real = real.reshape(G, n_batches, k)

    x_ext = jnp.concatenate([xf, jnp.zeros((G, 1, D), jnp.float32)], 1)
    if fair_codes is not None and categories is None:
        raise ValueError("fair_codes requires categories (the joint "
                         "attribute cell drives the 4.3 rearrangement)")
    if categories is not None:
        # quota codes: A=1 plain categories (code IS the category) or the
        # (G, M, A) multi-attribute fairness codes sharing one count axis
        if fair_codes is not None:
            if n_fair_codes <= 0:
                raise ValueError("n_fair_codes must be set with fair_codes")
            codes_i = fair_codes.astype(jnp.int32)
            n_codes = n_fair_codes
        else:
            codes_i = cat_i[..., None]
            n_codes = n_categories
        codes_ext = jnp.concatenate(
            [codes_i, jnp.zeros((G, 1, codes_i.shape[-1]), jnp.int32)], 1)

    # --- batch 1 initializes centroids ---------------------------------------
    first_idx = jnp.minimum(batches[:, 0], M)
    centroids0 = jnp.take_along_axis(x_ext, first_idx[..., None], axis=1)
    counts0 = real[:, 0].astype(jnp.int32)
    labels0 = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (G, k))
    if categories is not None:
        valid_i = (jnp.ones((G, M), jnp.int32) if valid_mask is None
                   else valid_mask.astype(jnp.int32))
        ub = -(-jnp.maximum(
            jnp.zeros((G, n_codes), jnp.int32).at[
                garange[..., None], codes_i].add(valid_i[..., None]),
            0) // k)  # (G, n_codes): ceil(|N_code| / k) per group
        cat_counts0 = (
            jnp.zeros((G, k, n_codes), jnp.int32)
            .at[garange[..., None], labels0[..., None],
                jnp.take_along_axis(codes_ext, first_idx[..., None], axis=1)]
            .add(real[:, 0].astype(jnp.int32)[..., None]))
    else:
        ub = None
        cat_counts0 = jnp.zeros((G, k, 1), jnp.int32)

    prices_in = (None if prices is None
                 else jnp.asarray(prices, jnp.float32))
    if n_batches == 1:
        out = jnp.zeros((G, M + 1), jnp.int32).at[
            garange, first_idx].set(labels0, mode="drop")
        if return_state:
            p_out = (jnp.zeros((G, k), jnp.float32) if prices_in is None
                     else prices_in)
            state = {"prices": p_out, "mu": mu}
            if telemetry:
                state["telemetry"] = None  # no batch LAP ran
            return out[:, :M], state
        return out[:, :M]

    # --- scan over remaining batches: one (G, k, k) LAP stack per step -----
    fused = (solver_obj.factored is not None and ub is None)
    # telemetry statically downgrades to None when the resolved solve path
    # has no stats twin (greedy/scipy/custom backends)
    stats_fn = None
    if telemetry:
        stats_fn = (solver_obj.factored_stats if fused
                    else solver_obj.solve_stats)
    p_init = (jnp.zeros((G, k), jnp.float32) if prices_in is None
              else prices_in)

    def step(carry, inp):
        cents, counts, cat_counts, _p_last = carry
        idx, is_real = inp  # (G, k) each
        xb = jnp.take_along_axis(x_ext, jnp.minimum(idx, M)[..., None], axis=1)
        cb = (jnp.take_along_axis(codes_ext, jnp.minimum(idx, M)[..., None],
                                  axis=1)
              if ub is not None else None)
        # every batch warm-starts from the SAME carried epoch prices (not the
        # previous batch's): the cold path (prices=None -> per-batch zeros)
        # stays bit-identical, and warm prices never compound across batches
        cents, new_counts, cat_counts, assign, p_out, stats = _assign_batch(
            solver_obj, fused, auction_config, cents, counts, cat_counts,
            xb, is_real, cb=cb, ub=ub, prices=prices_in, stats_fn=stats_fn)
        if stats_fn is None:
            return (cents, new_counts, cat_counts, p_out), assign
        return (cents, new_counts, cat_counts, p_out), (assign, stats)

    tele = None
    if stats_fn is None:
        (_, _, _, prices_f), assigns = jax.lax.scan(
            step, (centroids0, counts0, cat_counts0, p_init),
            (batches[:, 1:].swapaxes(0, 1), real[:, 1:].swapaxes(0, 1)))
    else:
        (_, _, _, prices_f), (assigns, tele) = jax.lax.scan(
            step, (centroids0, counts0, cat_counts0, p_init),
            (batches[:, 1:].swapaxes(0, 1), real[:, 1:].swapaxes(0, 1)))

    labels_all = jnp.concatenate(
        [labels0[:, None], assigns.swapaxes(0, 1)], axis=1)  # (G, B, k)
    out = jnp.zeros((G, M + 1), jnp.int32).at[
        garange, jnp.minimum(order_p, M)
    ].set(labels_all.reshape(G, -1), mode="drop")
    # padding rows of the *input* keep whatever label they drew (callers mask)
    if return_state:
        state = {"prices": prices_f, "mu": mu}
        if telemetry:
            state["telemetry"] = tele
        return out[:, :M], state
    return out[:, :M]


# ---------------------------------------------------------------------------
# The streaming (chunked, matrix-free) core
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("k", "chunk_size", "variant", "n_categories",
                     "n_fair_codes", "solver", "auction_config",
                     "return_state", "telemetry"),
)
def aba_stream(
    x: jnp.ndarray,
    k: int,
    chunk_size: int,
    *,
    variant: Variant = "base",
    categories: jnp.ndarray | None = None,
    n_categories: int = 0,
    fair_codes: jnp.ndarray | None = None,
    n_fair_codes: int = 0,
    valid_mask: jnp.ndarray | None = None,
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
    prices: jnp.ndarray | None = None,
    return_state: bool = False,
    telemetry: bool = False,
) -> jnp.ndarray:
    """Streaming ABA on flat ``(n, d)`` features: Algorithm 1 in fixed-size
    chunks, for n far beyond what the dense core's working set allows.

    The dense core materializes a permuted copy of the whole dataset (its
    ``x_ext`` gather is O(n*d)); here the centrality pass uses running
    moments (one scan for the global centroid, one chunked distance pass),
    and the assignment phase is a two-level scan -- outer over chunks of
    ``chunk_size`` rows (ONE (chunk, d) gather each), inner over the chunk's
    n/k batches -- so peak live memory beyond the input is
    O(chunk_size * d + k * d) in the feature dimension (plus the O(n)
    scalar dist/order/label vectors every path needs), not O(n * d): there
    is no concatenated/permuted dataset copy anywhere (chunks are dynamic
    slices; sentinel rows are clamped gathers masked by ``is_real``).  On
    TPU the per-chunk gather runs through the double-buffered DMA kernel
    (``repro.kernels.ops.gather_rows``) so the next chunk's row movement
    overlaps the current chunk's batch solves.  With a ``factored`` solver
    (e.g. "auction_fused") each batch's LAP is matrix-free on top: the
    (k, k) value matrix is never built either (`bid_top2` streams column
    tiles through VMEM on TPU).

    ``categories`` / ``fair_codes`` / ``valid_mask`` stream too (the bans
    lifted): the Section 4.3 rearrangement becomes a single pass over the
    centrality-sorted category stream -- an outer scan carries per-category
    running counts while each chunk ranks its rows locally with one
    (chunk, C) one-hot cumsum -- and the assignment scan carries the
    (k, n_codes) per-cluster quota counts, so the categorical working set is
    O(chunk * C + k * C) and never the dense (n, C) one-hot.  The rank pass
    is integer-exact, so the rearranged order is bit-identical to the dense
    categorical path at ANY chunk size; quota masking runs through the same
    ``_assign_batch`` as the dense core.

    Every batch runs through the same ``_assign_batch`` step as the dense
    core, so with ``chunk_size >= n`` the labels are bit-for-bit identical
    to ``aba_core(x[None], k)[0]`` with the same
    solver/variant/categories/fairness/mask (the parity contract tested in
    tests/test_anticluster.py and tests/test_stream_categorical.py).
    Larger chunks only change *memory*, never assignment order; smaller
    chunks are exactly equivalent too except that the global centroid is
    accumulated chunk by chunk (same sum, same result up to float summation
    order -- the permutation and all LAPs see identical inputs).

    Args:
      x: (n, d) float features.
      k: number of anticlusters (static).
      chunk_size: rows processed per outer step (static); rounded down to a
        multiple of k (at least one k-batch).
      variant: "base" | "interleave" | "auto" (same rule as ``aba_core``;
        categories take precedence, and the static interleave is skipped
        under ``valid_mask`` exactly like the dense core).
      categories: optional (n,) int32 in [0, n_categories) -- Section 4.3.
      n_categories: static category count (required with categories).
      fair_codes: optional (n, A) int32 multi-attribute quota codes (see
        ``aba_core``); requires ``categories`` (the joint attribute cell).
      n_fair_codes: static total code count (required with fair_codes).
      valid_mask: optional (n,) bool; False rows are padding (arbitrary
        labels, masked out of moments/quotas), same contract as the dense
        core.
      solver / auction_config: LAP backend (registry name) and schedule.
      prices: optional (1, k) float32 warm-start prices, same contract as
        ``aba_core`` (every batch LAP starts from this carried vector; None
        is the bit-identical cold path).
      return_state: also return ``{"prices": (1, k), "mu": (d,)}`` -- the
        final batch's prices and the running-moment global centroid.
      telemetry: (requires ``return_state``) the state dict additionally
        carries ``"telemetry"``: the solver's per-batch stats pytree with
        leading axis ``n_batches - 1`` (the chunk structure flattened back
        out and the sentinel pad batches dropped, so the layout matches the
        dense core's), or ``None`` when the resolved solve path has no
        telemetry twin or only one batch runs.  Labels/prices stay
        bit-identical; the flag is static (default executable untouched).

    Returns:
      (n,) int32 labels in [0, k); with ``return_state`` a
      ``(labels, state)`` tuple.
    """
    n, d = x.shape
    if k > n:
        raise ValueError(f"k={k} > n={n}")
    if telemetry and not return_state:
        raise ValueError("telemetry=True requires return_state=True (the "
                         "stats pytree rides the state dict)")
    solver_obj = get_solver(solver)
    xf = x.astype(jnp.float32)
    cpb = max(1, int(chunk_size) // k)  # batches per chunk
    chunk = cpb * k
    vm = None if valid_mask is None else valid_mask.astype(jnp.bool_)
    if fair_codes is not None and categories is None:
        raise ValueError("fair_codes requires categories (the joint "
                         "attribute cell drives the 4.3 rearrangement)")
    if categories is not None:
        if n_categories <= 0:
            raise ValueError("n_categories must be set with categories")
        cat_i = categories.astype(jnp.int32)
        if fair_codes is not None:
            if n_fair_codes <= 0:
                raise ValueError("n_fair_codes must be set with fair_codes")
            codes_i = fair_codes.astype(jnp.int32)   # (n, A)
            n_codes = n_fair_codes
        else:
            codes_i = cat_i[:, None]                 # A = 1: code IS the cat
            n_codes = n_categories

    # --- centrality: running moments + chunked distance pass ---------------
    # No padded O(n*d) copy: chunks are dynamic slices of the input.  The
    # tail chunk is clamped to the last `chunk` rows and masks its overlap
    # with the previous chunk (overlapping *distances* recompute to the same
    # values, so the update-slice reassembly is idempotent there).
    n_chunks = -(-n // chunk)
    if int(chunk_size) >= n or n_chunks == 1:
        # One covering chunk: identical ops to the dense core.  Keyed on the
        # *requested* chunk_size, not the k-rounded chunk, so the bit-parity
        # contract "chunk_size >= n == dense labels" holds structurally
        # (rounding down to a k-multiple must not switch the float reduction
        # order of the centrality mean).
        if vm is None:
            mu = jnp.mean(xf, axis=0)
            dist = jnp.sum((xf - mu[None, :]) ** 2, axis=-1)
        else:
            w = vm.astype(jnp.float32)
            mu = jnp.sum(xf * w[:, None], axis=0) / jnp.maximum(
                jnp.sum(w), 1.0)
            dist = jnp.where(vm,
                             jnp.sum((xf - mu[None, :]) ** 2, axis=-1),
                             -jnp.inf)  # padding sorts to the end
    else:
        starts = jnp.minimum(
            jnp.arange(n_chunks, dtype=jnp.int32) * chunk, n - chunk)
        offs = jnp.arange(n_chunks, dtype=jnp.int32) * chunk - starts
        crange = jnp.arange(chunk, dtype=jnp.int32)

        if vm is None:
            def moment_step(acc, inp):
                s, off = inp
                xc = jax.lax.dynamic_slice(xf, (s, 0), (chunk, d))
                w = (crange >= off).astype(jnp.float32)[:, None]
                return acc + jnp.sum(xc * w, axis=0), None

            total, _ = jax.lax.scan(
                moment_step, jnp.zeros((d,), jnp.float32), (starts, offs))
            mu = total / n
        else:
            def moment_step(acc, inp):
                s, off = inp
                xc = jax.lax.dynamic_slice(xf, (s, 0), (chunk, d))
                wc = jnp.logical_and(
                    crange >= off,
                    jax.lax.dynamic_slice(vm, (s,), (chunk,)))
                wf = wc.astype(jnp.float32)
                tot, cnt = acc
                return (tot + jnp.sum(xc * wf[:, None], axis=0),
                        cnt + jnp.sum(wf)), None

            (total, cnt), _ = jax.lax.scan(
                moment_step,
                (jnp.zeros((d,), jnp.float32), jnp.zeros((), jnp.float32)),
                (starts, offs))
            mu = total / jnp.maximum(cnt, 1.0)

        def dist_step(buf, inp):
            s, _off = inp
            xc = jax.lax.dynamic_slice(xf, (s, 0), (chunk, d))
            dc = jnp.sum((xc - mu[None, :]) ** 2, axis=-1)
            return jax.lax.dynamic_update_slice(buf, dc, (s,)), None

        dist, _ = jax.lax.scan(
            dist_step, jnp.zeros((n,), jnp.float32), (starts, offs))
        if vm is not None:
            dist = jnp.where(vm, dist, -jnp.inf)
    order = jnp.argsort(-dist, stable=True).astype(jnp.int32)

    # --- rearrangement (same rules as the dense core) -----------------------
    if categories is not None:
        cat_sorted = cat_i[order]
        if vm is not None:
            # padding gets a virtual category that sorts last (dense rule)
            cat_sorted = jnp.where(vm[order], cat_sorted, n_categories - 1)
        # Single-pass rank-in-category over the sorted category stream: the
        # outer scan carries the (C,) per-category running counts, each
        # chunk ranks its rows locally with one (chunk, C) one-hot cumsum --
        # the dense (n, C) one-hot never materializes.  Integer-exact, so
        # the rearranged order is bit-identical to the dense categorical
        # path at ANY chunk size.
        rpad = n_chunks * chunk - n
        cs_p = (jnp.concatenate([cat_sorted, jnp.zeros((rpad,), jnp.int32)])
                if rpad else cat_sorted)
        in_rng = jnp.arange(n_chunks * chunk, dtype=jnp.int32) < n

        def rank_step(run, inp):
            cat_c, ok_c = inp
            oh = (jax.nn.one_hot(cat_c, n_categories, dtype=jnp.int32)
                  * ok_c.astype(jnp.int32)[:, None])
            local = jnp.cumsum(oh, axis=0) - oh
            r = run[cat_c] + jnp.take_along_axis(
                local, cat_c[:, None], axis=1)[:, 0]
            return run + jnp.sum(oh, axis=0), r

        cat_counts, ranks = jax.lax.scan(
            rank_step, jnp.zeros((n_categories,), jnp.int32),
            (cs_p.reshape(n_chunks, chunk), in_rng.reshape(n_chunks, chunk)))
        rank_in_cat = ranks.reshape(-1)[:n]
        order = jnp.take_along_axis(
            order[None],
            categorical_sort_order(cat_sorted[None], rank_in_cat[None],
                                   cat_counts[None], k), axis=1)[0]
    elif (variant == "interleave" or (variant == "auto" and n // k <= 8)) \
            and vm is None:
        order = order[jnp.asarray(interleave_permutation(n, k))]
    # (interleave + valid_mask: same dense-core rule -- fall back to base)

    # --- pad to full batches, then to full chunks ---------------------------
    n_batches = -(-n // k)
    order_p = (jnp.concatenate([order, jnp.full((n_batches * k - n,), n,
                                                jnp.int32)])
               if n_batches * k > n else order)
    real = order_p < n
    if vm is not None:
        real = jnp.logical_and(real, vm[jnp.minimum(order_p, n - 1)])
    batches = order_p.reshape(n_batches, k)
    real_b = real.reshape(n_batches, k)

    # Sentinel indices (== n) clamp to the last row instead of indexing a
    # concatenated zero-row copy: a clamped gather avoids the dense core's
    # O(n*d) ``x_ext`` duplicate, and every consumer of a dummy row's values
    # masks them with ``is_real`` (cost neutralized, centroid delta zeroed,
    # quota add zeroed), so the clamped garbage never leaks -- labels stay
    # bit-identical.

    # --- batch 1 initializes centroids ---------------------------------------
    first_idx = jnp.minimum(batches[0], n - 1)
    centroids0 = xf[first_idx][None]              # (1, k, d)
    counts0 = real_b[0].astype(jnp.int32)[None]   # (1, k)
    labels0 = jnp.arange(k, dtype=jnp.int32)
    if categories is not None:
        valid_i = (jnp.ones((n,), jnp.int32) if vm is None
                   else vm.astype(jnp.int32))
        # ceil(|N_code| / k) quota bounds over valid rows -- (1, n_codes)
        ub = -(-jnp.maximum(
            jnp.zeros((n_codes,), jnp.int32).at[codes_i].add(
                valid_i[:, None]), 0) // k)[None]
        cat0 = (jnp.zeros((k, n_codes), jnp.int32)
                .at[jnp.arange(k)[:, None], codes_i[first_idx]]
                .add(real_b[0].astype(jnp.int32)[:, None]))[None]
    else:
        ub = None
        cat0 = jnp.zeros((1, k, 1), jnp.int32)
    prices_in = (None if prices is None
                 else jnp.asarray(prices, jnp.float32))
    if n_batches == 1:
        out1 = jnp.zeros((n + 1,), jnp.int32).at[first_idx].set(
            labels0, mode="drop")[:n]
        if return_state:
            p_out = (jnp.zeros((1, k), jnp.float32) if prices_in is None
                     else prices_in)
            state = {"prices": p_out, "mu": mu}
            if telemetry:
                state["telemetry"] = None  # no batch LAP ran
            return out1, state
        return out1

    # --- stream the remaining batches in chunks of cpb ----------------------
    rem = n_batches - 1
    n_bchunks = -(-rem // cpb)
    bpad = n_bchunks * cpb - rem
    idx_rest = batches[1:]
    real_rest = real_b[1:]
    if bpad:  # sentinel batches: all-dummy rows, a no-op for _assign_batch
        idx_rest = jnp.concatenate(
            [idx_rest, jnp.full((bpad, k), n, jnp.int32)])
        real_rest = jnp.concatenate(
            [real_rest, jnp.zeros((bpad, k), jnp.bool_)])
    idx_rest = idx_rest.reshape(n_bchunks, cpb, k)
    real_rest = real_rest.reshape(n_bchunks, cpb, k)

    # same rule as the dense core: the categorical quota mask cannot be
    # factored, so a factored solver falls back to its dense solve under it
    fused = solver_obj.factored is not None and categories is None
    # telemetry statically downgrades to None when the resolved solve path
    # has no stats twin (greedy/scipy/custom backends)
    stats_fn = None
    if telemetry:
        stats_fn = (solver_obj.factored_stats if fused
                    else solver_obj.solve_stats)
    p_init = (jnp.zeros((1, k), jnp.float32) if prices_in is None
              else prices_in)

    def chunk_step(carry, inp):
        cents, counts, ccat, p_last = carry
        idx_c, real_c = inp                      # (cpb, k)
        idx_g = jnp.minimum(idx_c, n - 1)
        # ONE (chunk, d) gather; double-buffered DMA kernel on TPU
        xc = gather_rows(xf, idx_g.reshape(-1)).reshape(cpb, k, d)
        if categories is not None:
            xs = (xc, real_c, codes_i[idx_g])    # + (cpb, k, A) code gather
        else:
            xs = (xc, real_c)

        def batch_step(bcarry, binp):
            bcents, bcounts, bcat, _bp = bcarry
            if categories is not None:
                xb, is_real, cb = binp           # (k, d), (k,), (k, A)
            else:
                (xb, is_real), cb = binp, None
            # same epoch-carried warm start per batch as the dense core
            bcents, bcounts, bcat, assign, p_out, stats = _assign_batch(
                solver_obj, fused, auction_config, bcents, bcounts, bcat,
                xb[None], is_real[None],
                cb=None if cb is None else cb[None], ub=ub,
                prices=prices_in, stats_fn=stats_fn)
            if stats_fn is None:
                return (bcents, bcounts, bcat, p_out), assign[0]
            return (bcents, bcounts, bcat, p_out), (assign[0], stats)

        (cents, counts, ccat, p_last), ys = jax.lax.scan(
            batch_step, (cents, counts, ccat, p_last), xs)
        return (cents, counts, ccat, p_last), ys  # assigns (cpb, k) [+stats]

    tele = None
    if stats_fn is None:
        (_, _, _, prices_f), assigns = jax.lax.scan(
            chunk_step, (centroids0, counts0, cat0, p_init),
            (idx_rest, real_rest))
    else:
        (_, _, _, prices_f), (assigns, tele_ck) = jax.lax.scan(
            chunk_step, (centroids0, counts0, cat0, p_init),
            (idx_rest, real_rest))
        # (n_bchunks, cpb, ...) -> (n_batches - 1, ...): flatten the chunk
        # structure and drop the sentinel pad batches, matching aba_core's
        # per-batch layout
        tele = jax.tree_util.tree_map(
            lambda a: a.reshape((n_bchunks * cpb,) + a.shape[2:])[:rem],
            tele_ck)

    labels_all = jnp.concatenate(
        [labels0, assigns.reshape(-1)[:rem * k]])
    out = jnp.zeros((n + 1,), jnp.int32).at[jnp.minimum(order_p, n)].set(
        labels_all, mode="drop")
    if return_state:
        state = {"prices": prices_f, "mu": mu}
        if telemetry:
            state["telemetry"] = tele
        return out[:n], state
    return out[:n]


def delta_moments(moment_sum: jnp.ndarray, moment_count: jnp.ndarray,
                  added: jnp.ndarray | None = None,
                  removed: jnp.ndarray | None = None,
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge arrivals/departures into carried centrality moments.

    ``moment_sum`` ((d,) feature sum over valid rows) and ``moment_count``
    (() valid-row count) are the running moments :class:`ABAState` carries
    behind the level-1 centrality sort -- the same mergeable pair
    ``aba_stream`` accumulates chunk by chunk.  ``added`` / ``removed`` are
    the delta's row blocks ((m, d) / (r, d)); the update is exact: the
    returned moments equal the from-scratch moments of the post-delta
    dataset up to float summation order.
    """
    moment_sum = jnp.asarray(moment_sum, jnp.float32)
    moment_count = jnp.asarray(moment_count, jnp.float32)
    if removed is not None and removed.shape[0]:
        moment_sum = moment_sum - jnp.sum(
            jnp.asarray(removed, jnp.float32), axis=0)
        moment_count = moment_count - float(removed.shape[0])
    if added is not None and added.shape[0]:
        moment_sum = moment_sum + jnp.sum(
            jnp.asarray(added, jnp.float32), axis=0)
        moment_count = moment_count + float(added.shape[0])
    return moment_sum, moment_count


# ---------------------------------------------------------------------------
# Deprecated shims (exact-parity wrappers over aba_core)
# ---------------------------------------------------------------------------

def aba(
    x: jnp.ndarray,
    k: int,
    *,
    variant: Variant = "auto",
    categories: jnp.ndarray | None = None,
    n_categories: int = 0,
    valid_mask: jnp.ndarray | None = None,
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
) -> jnp.ndarray:
    """Deprecated: flat ABA on (n, d).  Use ``repro.anticluster.anticluster``.

    Exactly ``aba_core`` with a leading group axis of size 1; labels are
    bit-for-bit identical to ``anticluster(x, AnticlusterSpec(k=k, ...))``.
    """
    _deprecated("aba", "repro.anticluster.anticluster(x, spec)")
    return aba_core(
        x[None], k,
        None if valid_mask is None else valid_mask[None],
        variant=variant,
        categories=None if categories is None else categories[None],
        n_categories=n_categories, solver=solver,
        auction_config=auction_config)[0]


def aba_batched(
    x: jnp.ndarray,
    k: int,
    valid_mask: jnp.ndarray,
    *,
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
) -> jnp.ndarray:
    """Deprecated: base-variant ABA on a (G, M, D) stack.  Use
    ``repro.anticluster.anticluster`` (it accepts the stacked rank directly).

    This IS ``aba_core`` -- the legacy name solved the stack with a dense
    batched engine, so a factored solver falls back to its dense path here.
    """
    _deprecated("aba_batched",
                "repro.anticluster.anticluster(x, spec) on a (G, M, D) stack")
    solver = "auction" if solver == "auction_fused" else solver
    return aba_core(x, k, valid_mask, variant="base", solver=solver,
                    auction_config=auction_config)


# ---------------------------------------------------------------------------
# Reference implementation (Algorithm 1 verbatim, numpy + exact Hungarian)
# ---------------------------------------------------------------------------

def aba_reference(x: np.ndarray, k: int, *, variant: Variant = "base",
                  categories: np.ndarray | None = None) -> np.ndarray:
    """Direct transcription of Algorithm 1 with an exact LAP solver.

    Used as the oracle in tests and to quantify the auction solver's
    eps-optimality gap.  O(N K^2) like the paper's C code, but in numpy.
    """
    from scipy.optimize import linear_sum_assignment

    x = np.asarray(x, np.float64)
    n = x.shape[0]
    mu = x.mean(axis=0)
    dist = ((x - mu) ** 2).sum(axis=1)
    order = np.argsort(-dist, kind="stable")

    if categories is not None:
        categories = np.asarray(categories)
        g_count = np.bincount(categories)
        ub = -(-g_count // k)
        pieces_full, pieces_tail = [], []
        per_cat = {g: order[categories[order] == g] for g in range(len(g_count))}
        max_blocks = max((len(v) + k - 1) // k for v in per_cat.values())
        for b in range(max_blocks):
            for g, idxs in per_cat.items():
                blk = idxs[b * k:(b + 1) * k]
                (pieces_full if len(blk) == k else pieces_tail).append(blk)
        order = np.concatenate([p for p in pieces_full + pieces_tail if len(p)])
    elif variant == "interleave" or (variant == "auto" and n // k <= 8):
        order = order[interleave_permutation(n, k)]

    labels = np.full(n, -1, np.int64)
    labels[order[:k]] = np.arange(min(k, n))
    cents = x[order[:k]].copy()
    counts = np.ones(min(k, n), np.int64)
    cat_counts = None
    if categories is not None:
        cat_counts = np.zeros((k, len(g_count)), np.int64)
        np.add.at(cat_counts, (labels[order[:k]], categories[order[:k]]), 1)

    b = 1
    while b * k < n:
        idx = order[b * k:(b + 1) * k]
        xb = x[idx]
        cost = ((xb[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
        if categories is not None:
            cb = categories[idx]
            full = cat_counts[:, cb].T >= ub[cb][:, None]
            cost[full] = _MASK_COST
        rows, cols = linear_sum_assignment(cost, maximize=True)
        for r, c in zip(rows, cols):
            counts[c] += 1
            cents[c] += (xb[r] - cents[c]) / counts[c]
            labels[idx[r]] = c
            if cat_counts is not None:
                cat_counts[c, categories[idx[r]]] += 1
        b += 1
    return labels.astype(np.int32)
