"""The Assignment-Based Anticlustering algorithm (paper Section 4).

JAX implementation notes
------------------------
* The batch loop (Algorithm 1) is a ``lax.scan`` carrying the anticluster
  centroids and per-cluster counts.  It is inherently sequential -- each LAP
  depends on the centroids updated by the previous batch -- so parallelism
  comes from (a) the dense vectorized work inside one step (cost matrix +
  auction rounds) and (b) the hierarchical decomposition (Section 4.4), which
  we ``vmap``/``shard_map`` over independent subproblems.
* The LAP input drops the row-constant ``||x_j||^2`` term: adding a constant
  per row never changes the optimal assignment, so the cost matrix is just
  ``-2 x . mu^T + ||mu||^2`` -- one matmul (MXU) plus a bias.
* The Section 4.2 interleave rearrangement is a *static* permutation of sorted
  positions (depends only on N, K) and is precomputed in numpy at trace time.
* The Section 4.3 categorical rearrangement depends on data; it is expressed
  as a single lexicographic sort key so it stays jit/vmap-compatible.
* ``valid_mask`` supports padded subproblems (hierarchical level >= 2 gathers
  groups whose sizes differ by one into a fixed-shape batch).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import (AuctionConfig, auction_solve,
                                   auction_solve_factored, greedy_solve)

_MASK_COST = -1e9  # categorical upper-bound mask (paper 4.3)

Variant = Literal["auto", "base", "interleave"]


# ---------------------------------------------------------------------------
# Static rearrangements
# ---------------------------------------------------------------------------

def interleave_permutation(n: int, k: int) -> np.ndarray:
    """Section 4.2 rearrangement of *positions* 0..n-1 of the sorted list.

    Splits the sorted list into k sublists (short ones first when k does not
    divide n) and round-robins through them; the n - floor(n/k)*k leftovers
    (one per long sublist, nearest the global centroid) go to the end.
    """
    q, r = divmod(n, k)
    if q == 0:
        return np.arange(n)
    n_short = k - r  # sublists of length q; the remaining r have length q+1
    lengths = np.array([q] * n_short + [q + 1] * r)
    starts = np.concatenate([[0], np.cumsum(lengths)[:-1]])
    rounds = starts[None, :] + np.arange(q)[:, None]  # (q, k) round-robin
    perm = rounds.reshape(-1)
    if r:
        leftovers = starts[n_short:] + q
        perm = np.concatenate([perm, leftovers])
    return perm.astype(np.int32)


def categorical_sort_order(categories: jnp.ndarray, rank_in_cat: jnp.ndarray,
                           cat_counts: jnp.ndarray, k: int) -> jnp.ndarray:
    """Section 4.3: lexicographic order by (incomplete, block, category, pos).

    ``rank_in_cat`` is each object's 0-based position among objects of its
    category in centrality-sorted order.  The returned permutation yields the
    rearranged list: full K-blocks alternate across categories by block
    index; incomplete tail blocks come last in the same alternating order.
    """
    block = rank_in_cat // k
    pos = rank_in_cat % k
    n_g = cat_counts[categories]
    incomplete = ((block + 1) * k > n_g).astype(jnp.int32)
    # lexsort: last key is primary
    return jnp.lexsort((pos, categories, block, incomplete))


# ---------------------------------------------------------------------------
# Core scan
# ---------------------------------------------------------------------------

_SOLVERS = ("auction", "auction_fused", "greedy")


def _solve(cost: jnp.ndarray, solver: str, auction_config: AuctionConfig):
    if solver in ("auction", "auction_fused"):
        # auction_solve is batched-native: (k, k) and (B, k, k) both take
        # the same fused round loop.
        return auction_solve(cost, auction_config)
    if solver == "greedy":
        if cost.ndim == 3:
            return jax.vmap(greedy_solve)(cost)
        return greedy_solve(cost)
    raise ValueError(f"unknown solver {solver!r}; expected one of {_SOLVERS}")


@functools.partial(
    jax.jit,
    static_argnames=("k", "variant", "n_categories", "solver", "auction_config"),
)
def aba(
    x: jnp.ndarray,
    k: int,
    *,
    variant: Variant = "auto",
    categories: jnp.ndarray | None = None,
    n_categories: int = 0,
    valid_mask: jnp.ndarray | None = None,
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
) -> jnp.ndarray:
    """Assignment-Based Anticlustering (Algorithm 1 + variants 4.2/4.3).

    Args:
      x: (n, d) float features.
      k: number of anticlusters (static).
      variant: "base", "interleave" (Section 4.2), or "auto" (interleave when
        anticlusters are small, n/k <= 8, matching the paper's guidance).
      categories: optional (n,) int32 in [0, n_categories) -- Section 4.3.
      n_categories: static number of categories (required with categories).
      valid_mask: optional (n,) bool; False rows are padding -- they never
        influence real rows, but their returned labels are arbitrary in
        [0, k): callers must mask them out.
      solver: "auction" | "auction_fused" | "greedy".  "auction_fused" runs
        the LAP matrix-free: the bidding round's top-2 streams through the
        Pallas ``bid_top2`` kernel (TPU; ``interpret=True`` on CPU) instead
        of re-materializing the (k, k) value matrix every round.  It falls
        back to the dense auction when ``categories`` is set (the categorical
        upper-bound mask cannot be factored).

    Returns:
      (n,) int32 labels in [0, k).
    """
    n, _d = x.shape
    if k > n:
        raise ValueError(f"k={k} > n={n}")
    xf = x.astype(jnp.float32)
    n_valid = n if valid_mask is None else jnp.sum(valid_mask)

    # --- centrality sort (descending distance to global centroid) ----------
    if valid_mask is None:
        mu = jnp.mean(xf, axis=0)
        dist = jnp.sum((xf - mu[None]) ** 2, axis=1)
    else:
        w = valid_mask.astype(jnp.float32)
        mu = jnp.sum(xf * w[:, None], axis=0) / jnp.maximum(jnp.sum(w), 1.0)
        dist = jnp.where(valid_mask, jnp.sum((xf - mu[None]) ** 2, axis=1), -jnp.inf)
    order = jnp.argsort(-dist, stable=True)  # padding sorts to the end

    # --- rearrangement ------------------------------------------------------
    use_interleave = variant == "interleave" or (variant == "auto" and n // k <= 8)
    if categories is not None:
        if n_categories <= 0:
            raise ValueError("n_categories must be set with categories")
        cat_sorted = categories[order]
        if valid_mask is not None:
            # padding gets a virtual category that sorts last
            cat_sorted = jnp.where(valid_mask[order], cat_sorted, n_categories - 1)
        onehot = jax.nn.one_hot(cat_sorted, n_categories, dtype=jnp.int32)
        rank_in_cat = (jnp.cumsum(onehot, axis=0) - onehot)[
            jnp.arange(n), cat_sorted]
        cat_counts = jnp.sum(onehot, axis=0)
        order = order[categorical_sort_order(cat_sorted, rank_in_cat,
                                             cat_counts, k)]
    elif use_interleave and valid_mask is None:
        order = order[jnp.asarray(interleave_permutation(n, k))]
    # (interleave + valid_mask: the true n is dynamic, so the static
    #  rearrangement is unavailable; fall back to base order.)

    # --- pad to full batches -------------------------------------------------
    n_batches = -(-n // k)
    pad = n_batches * k - n
    order_p = jnp.concatenate([order, jnp.full((pad,), n, jnp.int32)]) if pad else order
    real = order_p < n
    if valid_mask is not None:
        vm_ext = jnp.concatenate([valid_mask, jnp.zeros((1,), jnp.bool_)])
        real = jnp.logical_and(real, vm_ext[jnp.minimum(order_p, n)])
    batches = order_p.reshape(n_batches, k)
    real = real.reshape(n_batches, k)

    x_ext = jnp.concatenate([xf, jnp.zeros((1, xf.shape[1]), jnp.float32)])
    if categories is not None:
        cat_ext = jnp.concatenate(
            [categories.astype(jnp.int32), jnp.zeros((1,), jnp.int32)])

    # --- batch 1 initializes centroids ---------------------------------------
    first_idx = jnp.minimum(batches[0], n)
    centroids0 = x_ext[first_idx]
    counts0 = real[0].astype(jnp.int32)
    labels0 = jnp.arange(k, dtype=jnp.int32)
    if categories is not None:
        ub = -(-jnp.maximum(
            jnp.zeros((n_categories,), jnp.int32).at[categories].add(
                1 if valid_mask is None else valid_mask.astype(jnp.int32)),
            0) // k)  # ceil(|N_g| / k)
        cat_counts0 = (
            jnp.zeros((k, n_categories), jnp.int32)
            .at[labels0, cat_ext[first_idx]]
            .add(real[0].astype(jnp.int32)))
    else:
        ub = None
        cat_counts0 = jnp.zeros((k, 1), jnp.int32)

    if n_batches == 1:
        out = jnp.zeros((n + 1,), jnp.int32).at[first_idx].set(labels0, mode="drop")
        return out[:n]

    # --- scan over remaining batches -----------------------------------------
    fused = solver == "auction_fused" and ub is None

    def step(carry, inp):
        cents, counts, cat_counts = carry
        idx, is_real = inp
        xb = x_ext[jnp.minimum(idx, n)]
        if fused:
            # matrix-free bidding: the (k, k) value matrix is never built;
            # each auction round is one fused bid_top2 kernel call.
            assign = auction_solve_factored(xb, cents, is_real=is_real,
                                            config=auction_config)
        else:
            # reduced cost: row-constant ||x||^2 dropped (LAP-invariant)
            cost = -2.0 * (xb @ cents.T) + jnp.sum(cents * cents, axis=1)[None, :]
            cost = jnp.where(is_real[:, None], cost, 0.0)  # neutral dummy rows
            if ub is not None:
                cb = cat_ext[jnp.minimum(idx, n)]
                full = cat_counts[:, cb].T >= ub[cb][:, None]  # (k_rows, k_cols)
                cost = jnp.where(jnp.logical_and(full, is_real[:, None]),
                                 _MASK_COST, cost)
            assign = _solve(cost, solver, auction_config)
        # centroid running mean: mu_k += (x - mu_k) / new_count  (Algorithm 1)
        new_counts = counts.at[assign].add(is_real.astype(jnp.int32))
        upd = jnp.zeros_like(cents).at[assign].add(
            jnp.where(is_real[:, None], xb - cents[assign], 0.0))
        cents = cents + upd / jnp.maximum(new_counts, 1)[:, None].astype(jnp.float32)
        if ub is not None:
            cat_counts = cat_counts.at[assign, cb].add(is_real.astype(jnp.int32))
        return (cents, new_counts, cat_counts), assign

    (_, _, _), assigns = jax.lax.scan(
        step, (centroids0, counts0, cat_counts0), (batches[1:], real[1:]))

    labels_all = jnp.concatenate([labels0[None], assigns], axis=0)  # (B, k)
    out = jnp.zeros((n + 1,), jnp.int32).at[
        jnp.minimum(batches.reshape(-1), n)
    ].set(labels_all.reshape(-1), mode="drop")
    # padding rows of the *input* keep label 0 (callers mask them out anyway)
    del n_valid
    return out[:n]


# ---------------------------------------------------------------------------
# Batched ABA over a stack of padded subproblems
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("k", "solver", "auction_config"))
def aba_batched(
    x: jnp.ndarray,
    k: int,
    valid_mask: jnp.ndarray,
    *,
    solver: str = "auction",
    auction_config: AuctionConfig = AuctionConfig(),
) -> jnp.ndarray:
    """Base-variant ABA on a stack of G padded subproblems at once.

    Semantically ``vmap(lambda xg, vm: aba(xg, k, valid_mask=vm))`` (the
    masked path ignores interleave/categories), but each scan step solves the
    whole (G, k, k) cost stack with ONE batched ``auction_solve`` call --
    hierarchical levels and sharded shards go through a single fused solver
    loop instead of G lock-stepped scalar solves.

    Args:
      x: (G, M, D) float features, groups padded to a common M.
      k: number of anticlusters per group (static).
      valid_mask: (G, M) bool; False rows are padding -- they never influence
        real rows, but their returned labels are arbitrary in [0, k): callers
        must mask them out (as ``hierarchical_aba`` does).
      solver: "auction" | "auction_fused" | "greedy" ("auction_fused" takes
        the dense batched engine here -- the fused kernel path is per-matrix).

    Returns:
      (G, M) int32 labels in [0, k).
    """
    G, M, D = x.shape
    if k > M:
        raise ValueError(f"k={k} > M={M}")
    solver = "auction" if solver == "auction_fused" else solver
    xf = x.astype(jnp.float32)
    garange = jnp.arange(G)[:, None]

    # --- per-group centrality sort (masked) --------------------------------
    w = valid_mask.astype(jnp.float32)
    mu = jnp.sum(xf * w[..., None], axis=1) / jnp.maximum(
        jnp.sum(w, axis=1), 1.0)[:, None]
    dist = jnp.where(valid_mask,
                     jnp.sum((xf - mu[:, None, :]) ** 2, axis=-1), -jnp.inf)
    order = jnp.argsort(-dist, axis=1, stable=True).astype(jnp.int32)

    # --- pad to full batches ------------------------------------------------
    n_batches = -(-M // k)
    pad = n_batches * k - M
    order_p = (jnp.concatenate([order, jnp.full((G, pad), M, jnp.int32)], 1)
               if pad else order)
    real = order_p < M
    vm_ext = jnp.concatenate([valid_mask, jnp.zeros((G, 1), jnp.bool_)], 1)
    real = jnp.logical_and(
        real, jnp.take_along_axis(vm_ext, jnp.minimum(order_p, M), axis=1))
    batches = order_p.reshape(G, n_batches, k)
    real = real.reshape(G, n_batches, k)

    x_ext = jnp.concatenate([xf, jnp.zeros((G, 1, D), jnp.float32)], 1)

    # --- batch 1 initializes centroids -------------------------------------
    first_idx = jnp.minimum(batches[:, 0], M)
    centroids0 = jnp.take_along_axis(x_ext, first_idx[..., None], axis=1)
    counts0 = real[:, 0].astype(jnp.int32)
    labels0 = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (G, k))

    if n_batches == 1:
        out = jnp.zeros((G, M + 1), jnp.int32).at[
            garange, first_idx].set(labels0, mode="drop")
        return out[:, :M]

    # --- scan over remaining batches: one (G, k, k) LAP stack per step -----
    def step(carry, inp):
        cents, counts = carry
        idx, is_real = inp  # (G, k) each
        xb = jnp.take_along_axis(x_ext, jnp.minimum(idx, M)[..., None], axis=1)
        # reduced cost: row-constant ||x||^2 dropped (LAP-invariant)
        cost = (-2.0 * jnp.einsum("gid,gjd->gij", xb, cents)
                + jnp.sum(cents * cents, axis=-1)[:, None, :])
        cost = jnp.where(is_real[..., None], cost, 0.0)  # neutral dummy rows
        assign = _solve(cost, solver, auction_config)  # (G, k) batched
        new_counts = counts.at[garange, assign].add(is_real.astype(jnp.int32))
        delta = xb - jnp.take_along_axis(cents, assign[..., None], axis=1)
        upd = jnp.zeros_like(cents).at[garange, assign].add(
            jnp.where(is_real[..., None], delta, 0.0))
        cents = cents + upd / jnp.maximum(
            new_counts, 1)[..., None].astype(jnp.float32)
        return (cents, new_counts), assign

    (_, _), assigns = jax.lax.scan(
        step, (centroids0, counts0),
        (batches[:, 1:].swapaxes(0, 1), real[:, 1:].swapaxes(0, 1)))

    labels_all = jnp.concatenate(
        [labels0[:, None], assigns.swapaxes(0, 1)], axis=1)  # (G, B, k)
    out = jnp.zeros((G, M + 1), jnp.int32).at[
        garange, jnp.minimum(order_p, M)
    ].set(labels_all.reshape(G, -1), mode="drop")
    # padding rows of the *input* keep whatever label they drew (callers mask)
    return out[:, :M]


# ---------------------------------------------------------------------------
# Reference implementation (Algorithm 1 verbatim, numpy + exact Hungarian)
# ---------------------------------------------------------------------------

def aba_reference(x: np.ndarray, k: int, *, variant: Variant = "base",
                  categories: np.ndarray | None = None) -> np.ndarray:
    """Direct transcription of Algorithm 1 with an exact LAP solver.

    Used as the oracle in tests and to quantify the auction solver's
    eps-optimality gap.  O(N K^2) like the paper's C code, but in numpy.
    """
    from scipy.optimize import linear_sum_assignment

    x = np.asarray(x, np.float64)
    n = x.shape[0]
    mu = x.mean(axis=0)
    dist = ((x - mu) ** 2).sum(axis=1)
    order = np.argsort(-dist, kind="stable")

    if categories is not None:
        categories = np.asarray(categories)
        g_count = np.bincount(categories)
        ub = -(-g_count // k)
        pieces_full, pieces_tail = [], []
        per_cat = {g: order[categories[order] == g] for g in range(len(g_count))}
        max_blocks = max((len(v) + k - 1) // k for v in per_cat.values())
        for b in range(max_blocks):
            for g, idxs in per_cat.items():
                blk = idxs[b * k:(b + 1) * k]
                (pieces_full if len(blk) == k else pieces_tail).append(blk)
        order = np.concatenate([p for p in pieces_full + pieces_tail if len(p)])
    elif variant == "interleave" or (variant == "auto" and n // k <= 8):
        order = order[interleave_permutation(n, k)]

    labels = np.full(n, -1, np.int64)
    labels[order[:k]] = np.arange(min(k, n))
    cents = x[order[:k]].copy()
    counts = np.ones(min(k, n), np.int64)
    cat_counts = None
    if categories is not None:
        cat_counts = np.zeros((k, len(g_count)), np.int64)
        np.add.at(cat_counts, (labels[order[:k]], categories[order[:k]]), 1)

    b = 1
    while b * k < n:
        idx = order[b * k:(b + 1) * k]
        xb = x[idx]
        cost = ((xb[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
        if categories is not None:
            cb = categories[idx]
            full = cat_counts[:, cb].T >= ub[cb][:, None]
            cost[full] = _MASK_COST
        rows, cols = linear_sum_assignment(cost, maximize=True)
        for r, c in zip(rows, cols):
            counts[c] += 1
            cents[c] += (xb[r] - cents[c]) / counts[c]
            labels[idx[r]] = c
            if cat_counts is not None:
                cat_counts[c, categories[idx[r]]] += 1
        b += 1
    return labels.astype(np.int32)
