"""Benchmark baselines from the paper's experimental study (Section 5.2).

- ``random_partition``      the paper's Rand baseline (balanced sizes).
- ``fast_anticlustering``   Papenberg & Klau's exchange heuristic with a
  limited number of exchange partners (P-N5 / P-R5 / P-R50 / P-R500).  Uses
  the centroid-form objective delta (their "fast" formulation) so one
  exchange evaluation is O(D), and is vectorized over objects per sweep.
- ``exchange_anticlustering``  the same exchange move set vectorized over
  all object/partner pairs per round (cluster-disjoint swap matching keeps
  every applied gain exact) -- the variant fast enough to run as the
  competitive frame in ``benchmarks/table10_scale.py``.
- ``greedy_kcut``           balanced k-cut via greedy refinement on the
  complete sq-Euclidean graph -- stands in for METIS (Section 5.5), which we
  do not reimplement (multilevel graph coarsening is out of scope; noted in
  DESIGN.md).  The cut-cost equivalence of Section 5.5 lets it reuse the
  anticlustering machinery.
- ``exact_small``           brute force over set partitions for tiny N
  (replaces the MILP/Gurobi reference in optimality-gap tests).
"""

from __future__ import annotations

import itertools

import numpy as np


def random_partition(n: int, k: int, seed: int = 0,
                     categories: np.ndarray | None = None) -> np.ndarray:
    """Balanced random labels; with categories, balanced per category (5)."""
    rng = np.random.default_rng(seed)
    labels = np.empty(n, np.int32)
    if categories is None:
        perm = rng.permutation(n)
        labels[perm] = np.arange(n) % k
        return labels
    for g in np.unique(categories):
        idx = np.flatnonzero(categories == g)
        perm = rng.permutation(len(idx))
        labels[idx[perm]] = np.arange(len(idx)) % k
    return labels


def _centroid_state(x: np.ndarray, labels: np.ndarray, k: int):
    sums = np.zeros((k, x.shape[1]))
    counts = np.bincount(labels, minlength=k).astype(np.float64)
    np.add.at(sums, labels, x)
    return sums, counts


def fast_anticlustering(
    x: np.ndarray,
    k: int,
    *,
    n_partners: int = 5,
    partner_mode: str = "random",  # "random" (P-R*) or "nearest" (P-N*)
    seed: int = 0,
    categories: np.ndarray | None = None,
    n_sweeps: int = 1,
) -> np.ndarray:
    """Exchange heuristic of Papenberg & Klau [2021] (the paper's main rival).

    Starts from a balanced random partition; for each object, evaluates
    swapping with ``n_partners`` exchange partners (same category when
    ``categories`` is given) and performs the best improving swap.  The
    objective delta uses the k-means identity: moving object i from cluster a
    to b changes sum_k n_k*Var_k via centroid updates only -- O(D) per
    candidate, as in the R package's fast_anticlustering().
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    labels = random_partition(n, k, seed=seed, categories=categories)
    sums, counts = _centroid_state(x, labels, k)

    def cluster_gain(i, j):
        """Objective delta of swapping labels of i and j (centroid form)."""
        a, b = labels[i], labels[j]
        if a == b:
            return 0.0
        # d_k = sum ||x||^2 - ||sum x||^2 / n_k  per cluster; only the
        # -||S_k||^2/n_k terms change (counts are preserved by a swap).
        sa, sb = sums[a], sums[b]
        na, nb = counts[a], counts[b]
        delta = x[j] - x[i]
        old = -(sa @ sa) / na - (sb @ sb) / nb
        sa2, sb2 = sa + delta, sb - delta
        new = -(sa2 @ sa2) / na - (sb2 @ sb2) / nb
        return new - old

    if partner_mode == "nearest":
        # nearest neighbours in feature space (the R package's default).
        # KD-trees degenerate above ~30 dims (mnist/cifar would take hours);
        # use chunked brute force there, exact same neighbours.
        if x.shape[1] <= 30:
            from scipy.spatial import cKDTree

            tree = cKDTree(x)
            _, nn = tree.query(x, k=n_partners + 1)
            partner_table = nn[:, 1:]
        else:
            sq = (x * x).sum(1)
            parts = []
            for lo in range(0, n, 2048):
                d = sq[lo:lo + 2048, None] - 2.0 * (x[lo:lo + 2048] @ x.T) \
                    + sq[None, :]
                idx = np.argpartition(d, n_partners + 1, axis=1)[
                    :, :n_partners + 1]
                # drop self, keep n_partners
                rows = []
                for r, row in enumerate(idx):
                    row = row[row != lo + r][:n_partners]
                    rows.append(row)
                parts.append(np.stack(rows))
            partner_table = np.concatenate(parts)
    else:
        partner_table = rng.integers(0, n, size=(n, n_partners))

    for _ in range(n_sweeps):
        for i in range(n):
            cands = partner_table[i]
            if categories is not None:
                cands = cands[categories[cands] == categories[i]]
            best_gain, best_j = 0.0, -1
            for j in cands:
                if labels[j] == labels[i]:
                    continue
                g = cluster_gain(i, int(j))
                if g > best_gain + 1e-12:
                    best_gain, best_j = g, int(j)
            if best_j >= 0:
                a, b = labels[i], labels[best_j]
                delta = x[best_j] - x[i]
                sums[a] += delta
                sums[b] -= delta
                labels[i], labels[best_j] = b, a
    return labels


def exchange_anticlustering(
    x: np.ndarray,
    k: int,
    *,
    n_partners: int = 8,
    n_sweeps: int = 3,
    seed: int = 0,
    max_rounds: int = 64,
) -> np.ndarray:
    """Vectorized exchange heuristic -- ``fast_anticlustering`` at scale.

    Same move set and same centroid-form O(D) gain as
    :func:`fast_anticlustering` (Papenberg & Klau's P-R* scheme), but
    evaluated for *every* object x partner pair at once in numpy instead of
    a Python loop per object, so it is usable as the paper's competitive
    frame at ``table10_scale`` sizes.  Each round applies the best
    improving swaps under a cluster-disjoint matching (each cluster touched
    by at most one swap per round): swaps on disjoint cluster pairs have
    additive objective deltas, so every applied gain is exact -- no stale
    centroid sums.  Rounds repeat until no candidate improves (or
    ``max_rounds``); each sweep redraws the random partner table.

    Returns balanced labels (swaps preserve cluster sizes by construction).
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    labels = random_partition(n, k, seed=seed)
    sums, counts = _centroid_state(x, labels, k)
    rows = np.arange(n)

    for _ in range(n_sweeps):
        partners = rng.integers(0, n, size=(n, n_partners))
        for _round in range(max_rounds):
            a = labels[:, None]                       # (n, 1)
            b = labels[partners]                      # (n, P)
            delta = x[partners] - x[:, None, :]       # (n, P, d)
            # gain of swapping i<->j: only the -||S||^2/n_c terms move
            # (counts are preserved); expand ||S +- delta||^2:
            #   -(2 S_a.delta + ||delta||^2)/n_a + (2 S_b.delta - ||d||^2)/n_b
            d2 = np.einsum("npd,npd->np", delta, delta)
            sa_d = np.einsum("npd,npd->np",
                             np.broadcast_to(sums[labels][:, None, :],
                                             delta.shape), delta)
            sb_d = np.einsum("npd,npd->np", sums[b], delta)
            gain = (-(2.0 * sa_d + d2) / counts[a]
                    + (2.0 * sb_d - d2) / counts[b])
            gain[a == b] = 0.0
            best_p = np.argmax(gain, axis=1)          # best partner per i
            best_g = gain[rows, best_p]
            order = np.argsort(-best_g)
            used_obj = np.zeros(n, bool)
            used_cluster = np.zeros(k, bool)
            applied = False
            for i in order:
                g = best_g[i]
                if g <= 1e-9:
                    break
                j = partners[i, best_p[i]]
                ca, cb = labels[i], labels[j]
                if (used_obj[i] or used_obj[j]
                        or used_cluster[ca] or used_cluster[cb]):
                    continue
                dlt = x[j] - x[i]
                sums[ca] += dlt
                sums[cb] -= dlt
                labels[i], labels[j] = cb, ca
                used_obj[i] = used_obj[j] = True
                used_cluster[ca] = used_cluster[cb] = True
                applied = True
            if not applied:
                break
    return labels


def greedy_kcut(x: np.ndarray, k: int, *, seed: int = 0,
                n_sweeps: int = 2, n_partners: int = 30) -> np.ndarray:
    """Balanced k-cut proxy for METIS: random init + swap refinement.

    Minimizing the cut on the complete sq-Euclidean graph equals maximizing
    W(C) (Section 5.5), so refinement reuses the exchange machinery with a
    neighbour list of ``n_partners`` random peers (METIS was run by the paper
    on 30-random-neighbour sparsifications -- same information budget).
    """
    return fast_anticlustering(x, k, n_partners=n_partners, seed=seed,
                               n_sweeps=n_sweeps, partner_mode="random")


def exact_small(x: np.ndarray, k: int) -> tuple[np.ndarray, float]:
    """Exhaustive optimum for tiny instances (N <= ~12). Returns labels, W(C)."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    assert n % k == 0 and n <= 12, "exact_small is for tiny sanity checks"
    size = n // k
    d = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)

    best_val, best_labels = -1.0, None

    def rec(remaining: frozenset, labels: np.ndarray, g: int):
        nonlocal best_val, best_labels
        if not remaining:
            val = sum(d[i, j] for i in range(n) for j in range(i + 1, n)
                      if labels[i] == labels[j])
            if val > best_val:
                best_val, best_labels = val, labels.copy()
            return
        first = min(remaining)
        rest = remaining - {first}
        for combo in itertools.combinations(sorted(rest), size - 1):
            group = (first,) + combo
            for i in group:
                labels[i] = g
            rec(rest - set(combo), labels, g + 1)
        for i in [first]:
            labels[i] = -1

    rec(frozenset(range(n)), np.full(n, -1), 0)
    return best_labels.astype(np.int32), float(best_val)
