"""Memory profiling: compiler-measured footprints + host peak-RSS sampling.

Two complementary views, because the ROADMAP streaming receipt ("O(chunk*d +
k*d), measured, not asserted") needs both:

* :func:`memory_profile` asks XLA what a jitted call *would* allocate --
  ``fn.lower(...).compile().memory_analysis()`` -- without ever running it.
  Temp (scratch) bytes are the honest "live memory beyond inputs/outputs"
  number the streaming-vs-dense comparison hinges on, and lowering is cheap
  enough to run inside a benchmark (generalizes the one-off ``_temp_bytes``
  that lived in ``benchmarks/table10_scale``).
* :func:`peak_rss_bytes` / :func:`rss_sampling` read the host side -- the
  process high-water mark (``VmHWM``) and a sampled during-call peak -- for
  paths XLA cannot see (host callbacks, NumPy staging, the router's queues).

Some CPU builds ship no memory analysis; :class:`MemoryProfile` then carries
``available=False`` and ``-1`` byte counts, and callers record that honestly
rather than failing (the BENCH rows keep the column, gated on wall only).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from contextlib import contextmanager


@dataclasses.dataclass(frozen=True)
class MemoryProfile:
    """Compiler-measured footprint of one jitted call (bytes; -1 unknown)."""

    temp_bytes: int = -1           # scratch: live memory beyond args/outputs
    argument_bytes: int = -1
    output_bytes: int = -1
    generated_code_bytes: int = -1
    available: bool = False

    @property
    def total_bytes(self) -> int:
        """Sum of the known components (-1 when none is known)."""
        known = [b for b in (self.temp_bytes, self.argument_bytes,
                             self.output_bytes, self.generated_code_bytes)
                 if b >= 0]
        return sum(known) if known else -1


def _mem_attr(mem, name: str) -> int:
    try:
        v = getattr(mem, name)
        return int(v) if v is not None else -1
    except Exception:
        return -1


def memory_profile(fn, *args, **kwargs) -> MemoryProfile:
    """XLA memory analysis for ``fn(*args, **kwargs)`` where ``fn`` is a
    jitted callable.  Lowers and compiles (does NOT execute); returns an
    ``available=False`` profile when the backend exposes no analysis."""
    try:
        mem = fn.lower(*args, **kwargs).compile().memory_analysis()
        if mem is None:
            return MemoryProfile()
        return MemoryProfile(
            temp_bytes=_mem_attr(mem, "temp_size_in_bytes"),
            argument_bytes=_mem_attr(mem, "argument_size_in_bytes"),
            output_bytes=_mem_attr(mem, "output_size_in_bytes"),
            generated_code_bytes=_mem_attr(mem, "generated_code_size_in_bytes"),
            available=True)
    except Exception:
        return MemoryProfile()


def _read_status_kb(field: str) -> int:
    """A ``/proc/self/status`` field in kB, or -1 off-Linux."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field + ":"):
                    return int(line.split()[1])
    except Exception:
        pass
    return -1


def current_rss_bytes() -> int:
    """Current resident set size in bytes (-1 when unavailable)."""
    kb = _read_status_kb("VmRSS")
    return kb * 1024 if kb >= 0 else -1


def peak_rss_bytes() -> int:
    """Process peak RSS (high-water mark) in bytes; -1 when unavailable."""
    kb = _read_status_kb("VmHWM")
    if kb >= 0:
        return kb * 1024
    try:
        import resource
        # Linux reports ru_maxrss in kB
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    except Exception:
        return -1


class RssSample:
    """Mutable holder filled by :func:`rss_sampling`."""

    __slots__ = ("peak_bytes", "samples")

    def __init__(self):
        self.peak_bytes = -1
        self.samples = 0


@contextmanager
def rss_sampling(interval_s: float = 0.01):
    """Sample current RSS on a daemon thread for the duration of the block;
    yields an :class:`RssSample` whose ``peak_bytes`` is the observed
    maximum (plus one final sample at exit)."""
    sample = RssSample()
    stop = threading.Event()

    def _poll():
        while not stop.is_set():
            rss = current_rss_bytes()
            if rss > sample.peak_bytes:
                sample.peak_bytes = rss
            sample.samples += 1
            stop.wait(interval_s)

    t = threading.Thread(target=_poll, daemon=True)
    t.start()
    try:
        yield sample
    finally:
        stop.set()
        t.join(timeout=5.0)
        rss = current_rss_bytes()
        if rss > sample.peak_bytes:
            sample.peak_bytes = rss
        sample.samples += 1


def sample_rss(fn, *args, interval_s: float = 0.01, **kwargs):
    """Run ``fn(*args, **kwargs)`` under RSS sampling; returns
    ``(result, peak_rss_bytes_during_call)``."""
    with rss_sampling(interval_s) as s:
        out = fn(*args, **kwargs)
    return out, s.peak_bytes


# re-exported for callers that want to timestamp samples themselves
monotonic = time.monotonic
