"""``repro.obs``: the unified tracing / metrics / profiling subsystem.

One import surface for every tier:

* tracing -- :func:`span` / :func:`begin` / :func:`event` instrument the
  engine, streaming driver, serving router, and training pipeline; enable
  with :func:`tracing` (scoped, JSONL export) or :func:`enable`; summarize
  with ``tools/trace_report.py``.  Disabled tracing is a single global read
  per call site and adds **zero** traced ops to compiled paths.
* metrics -- :class:`Histogram` backs the router's latency / queue-wait
  percentiles (``ServiceMetrics.latency_p50`` etc.).
* profiling -- :func:`memory_profile` (XLA ``memory_analysis`` on a lowered
  call) and :func:`peak_rss_bytes` / :func:`rss_sampling` (host side)
  produce the ``scale/memory/*`` BENCH rows.
* solver telemetry -- the auction solver's compiled-path stats pytree
  (rounds per eps phase, eps schedule, warm re-entry decisions) surfaces
  through ``AnticlusterSpec(telemetry=True)``;
  :func:`summarize_auction_telemetry` folds it to a small dict that span
  attrs and reports can carry.
"""

from __future__ import annotations

from .trace import (Histogram, Span, Trace, active, begin, disable, enable,
                    enabled, event, span, tracing)
from .memory import (MemoryProfile, RssSample, current_rss_bytes,
                     memory_profile, peak_rss_bytes, rss_sampling, sample_rss)

__all__ = [
    "Histogram", "Span", "Trace", "active", "begin", "disable", "enable",
    "enabled", "event", "span", "tracing",
    "MemoryProfile", "RssSample", "current_rss_bytes", "memory_profile",
    "peak_rss_bytes", "rss_sampling", "sample_rss",
    "summarize_auction_telemetry",
]


def summarize_auction_telemetry(tele) -> dict | None:
    """Fold a solver telemetry pytree (see ``repro.core.assignment``:
    ``rounds (B?, P)``, ``eps``, ``warm``, ``skipped`` stacked over batches)
    into a small JSON-friendly summary dict; None for None input."""
    if tele is None:
        return None
    import numpy as np

    rounds = np.asarray(tele["rounds"])
    if rounds.ndim == 1:                  # single solve: add a batch axis
        rounds = rounds[None]
    per_phase = rounds.sum(axis=0)
    out = {
        "batches": int(rounds.shape[0]),
        "phases": int(rounds.shape[1]),
        "rounds_total": int(rounds.sum()),
        "rounds_per_phase": [int(r) for r in per_phase],
    }
    warm = tele.get("warm")
    if warm is not None and np.asarray(warm).size:
        out["warm_fraction"] = float(np.asarray(warm).mean())
    skipped = tele.get("skipped")
    if skipped is not None and np.asarray(skipped).size:
        out["skipped_fraction"] = float(np.asarray(skipped).mean())
    eps = tele.get("eps")
    if eps is not None and np.asarray(eps).size:
        e = np.asarray(eps, dtype=np.float64)
        # eps axis layout: (..., P, B) or (P, B); reduce to per-phase means
        flat = e.reshape(-1, e.shape[-2], e.shape[-1]) if e.ndim >= 2 \
            else e.reshape(1, -1, 1)
        out["eps_first"] = float(flat[..., 0, :].mean())
        out["eps_last"] = float(flat[..., -1, :].mean())
    return out
