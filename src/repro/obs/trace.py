"""Host-side structured tracing: spans, instant events, latency histograms.

The recorder is deliberately boring technology -- monotonic-clock spans in a
thread-safe in-memory buffer, exported as JSONL -- because the interesting
constraints are all *cost* constraints:

* **Off-by-default and cheap when off.**  Every instrumented call site goes
  through the module-level :func:`span` / :func:`event` helpers, which cost
  one global read and (for spans) return a shared no-op context manager when
  no trace is installed.  Nothing in a compiled (jit) path ever consults the
  recorder -- tracing never adds traced ops, which is what the engine
  ``compile_count`` pins in ``tests/test_obs.py`` verify.
* **Thread-safe.**  The serving router completes requests on a background
  worker while callers submit from their own threads; the event buffer takes
  a lock per *completed* span (not per running one) and span nesting is
  tracked per-thread with ``threading.local`` stacks, so concurrent spans
  never see each other's parents.
* **Nesting without bookkeeping at the call site.**  ``with span("a"):``
  inside ``with span("b"):`` records ``a.parent == b.id`` automatically.
  For spans that *cross* threads or stack frames (the engine's async
  dispatch -> wait, a pipeline epoch that spans a generator yield) use
  :meth:`Trace.begin` / :meth:`Span.finish` -- the span captures its parent
  at begin time but is not pushed on any stack.

Events are plain dicts (``name, ts, dur, id, parent, thread, attrs``);
``ts`` is seconds since the trace was created, ``dur`` is 0.0 for instant
events.  ``tools/trace_report.py`` summarizes the JSONL.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from contextlib import contextmanager


def _jsonable(v):
    """Best-effort conversion of an attr value to a JSON-serializable one."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    item = getattr(v, "item", None)  # numpy / jax scalars
    if item is not None:
        try:
            return _jsonable(item())
        except Exception:
            pass
    if isinstance(v, (list, tuple)):
        return [_jsonable(e) for e in v]
    return repr(v)


class Span:
    """One timed region.  Use as a context manager (stacked, from
    :meth:`Trace.span`) or begin/finish explicitly (:meth:`Trace.begin`)."""

    __slots__ = ("name", "attrs", "_trace", "_t0", "_parent", "_id",
                 "_stacked", "_done")

    def __init__(self, trace: "Trace", name: str, attrs: dict,
                 parent, stacked: bool):
        self.name = name
        self.attrs = attrs
        self._trace = trace
        self._parent = parent
        self._id = next(trace._ids)
        self._stacked = stacked
        self._done = False
        self._t0 = trace._clock()

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes on a running span."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        if self._stacked:
            stack = self._trace._stack()
            self._parent = stack[-1]._id if stack else None
            stack.append(self)
            self._t0 = self._trace._clock()  # exclude stack bookkeeping
        return self

    def __exit__(self, *exc) -> None:
        self.finish()

    def finish(self, **attrs) -> None:
        """Close the span (idempotent) and record it into the trace."""
        if self._done:
            return
        self._done = True
        t1 = self._trace._clock()
        if attrs:
            self.attrs.update(attrs)
        if self._stacked:
            stack = self._trace._stack()
            if stack and stack[-1] is self:
                stack.pop()
            elif self in stack:          # mis-nested exit; stay consistent
                stack.remove(self)
        self._trace._record(self.name, self._t0, t1, self._id,
                            self._parent, self.attrs)


class _NopSpan:
    """Shared do-nothing span for the disabled path (allocation-free)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def set(self, **attrs):
        return self

    def finish(self, **attrs):
        return None


_NOP = _NopSpan()


class Trace:
    """A thread-safe span/event recorder on a monotonic clock.

    ``clock`` is injectable (tests pin timings with a fake clock); it must
    be monotonic non-decreasing.  Completed events live in :attr:`events`
    in completion order.
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._ids = itertools.count(1)   # CPython-atomic id allocator
        self.events: list[dict] = []
        self._t0 = clock()

    # -- span lifecycle ---------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def span(self, name: str, **attrs) -> Span:
        """A stacked span: ``with trace.span("engine/solve", k=8): ...``.
        Parent is whatever span is open on this thread at ``__enter__``."""
        return Span(self, name, attrs, parent=None, stacked=True)

    def begin(self, name: str, **attrs) -> Span:
        """An async (non-stacked) span: starts now, parented under the
        current thread's open span, closed later via :meth:`Span.finish`
        (possibly from another thread)."""
        stack = self._stack()
        parent = stack[-1]._id if stack else None
        return Span(self, name, attrs, parent=parent, stacked=False)

    def event(self, name: str, **attrs) -> None:
        """An instant event (``dur == 0``) under the current open span."""
        now = self._clock()
        stack = self._stack()
        parent = stack[-1]._id if stack else None
        self._record(name, now, now, next(self._ids), parent, attrs)

    def _record(self, name, t0, t1, sid, parent, attrs) -> None:
        ev = {"name": name, "ts": t0 - self._t0, "dur": t1 - t0, "id": sid,
              "parent": parent, "thread": threading.get_ident(),
              "attrs": attrs}
        with self._lock:
            self.events.append(ev)

    # -- export -----------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self.events)

    def snapshot(self) -> list[dict]:
        """A consistent copy of the completed events."""
        with self._lock:
            return list(self.events)

    def export_jsonl(self, path: str) -> int:
        """Write one JSON object per completed event; returns the count."""
        events = self.snapshot()
        with open(path, "w") as f:
            for ev in events:
                out = dict(ev)
                out["attrs"] = {k: _jsonable(v)
                                for k, v in ev["attrs"].items()}
                f.write(json.dumps(out) + "\n")
        return len(events)


class Histogram:
    """Thread-safe bounded reservoir for latency-style samples.

    Keeps the last ``max_samples`` values in a ring (plus exact running
    count/sum), so percentiles over a smoke run are *exact* -- which is what
    lets the router tests pin ``latency_p50`` on a fake clock -- while a
    long-lived service degrades gracefully to a sliding window.
    """

    __slots__ = ("_lock", "_ring", "_pos", "_count", "_sum")

    def __init__(self, max_samples: int = 4096):
        if max_samples <= 0:
            raise ValueError("max_samples must be positive")
        self._lock = threading.Lock()
        self._ring: list[float] = [0.0] * max_samples
        self._pos = 0
        self._count = 0
        self._sum = 0.0

    def record(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self._ring[self._pos % len(self._ring)] = v
            self._pos += 1
            self._count += 1
            self._sum += v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Exact percentile of the retained window (nearest-rank); 0.0 when
        empty.  ``q`` in [0, 100]."""
        with self._lock:
            n = min(self._pos, len(self._ring))
            if n == 0:
                return 0.0
            vals = sorted(self._ring[:n])
        rank = max(1, min(n, -(-int(q * n) // 100)))  # ceil(q*n/100) clamped
        return vals[rank - 1]


# -- module-level switch ---------------------------------------------------
# _ACTIVE is read unlocked on the hot path: a torn read is impossible for a
# single reference assignment in CPython, and enable/disable are control
# operations, not data-path ones.
_ACTIVE: Trace | None = None


def enabled() -> bool:
    """True when a trace is installed (instrumentation will record)."""
    return _ACTIVE is not None


def active() -> Trace | None:
    """The installed :class:`Trace`, or None."""
    return _ACTIVE


def enable(trace: Trace | None = None) -> Trace:
    """Install ``trace`` (a fresh one by default) as the active recorder."""
    global _ACTIVE
    if trace is None:
        trace = Trace()
    _ACTIVE = trace
    return trace


def disable() -> Trace | None:
    """Uninstall and return the active trace (None when none was active)."""
    global _ACTIVE
    trace, _ACTIVE = _ACTIVE, None
    return trace


def span(name: str, **attrs):
    """Open a stacked span on the active trace; a shared no-op when
    tracing is disabled (the call site never branches)."""
    t = _ACTIVE
    return t.span(name, **attrs) if t is not None else _NOP


def begin(name: str, **attrs):
    """Begin an async span on the active trace (no-op when disabled)."""
    t = _ACTIVE
    return t.begin(name, **attrs) if t is not None else _NOP


def event(name: str, **attrs) -> None:
    """Record an instant event on the active trace (no-op when disabled)."""
    t = _ACTIVE
    if t is not None:
        t.event(name, **attrs)


@contextmanager
def tracing(path: str | None = None, clock=time.perf_counter):
    """Scoped tracing: install a fresh :class:`Trace`, restore the previous
    one on exit, and (optionally) export the JSONL to ``path``.

    >>> with tracing("TRACE.jsonl") as trace: ...
    """
    global _ACTIVE
    prev = _ACTIVE
    trace = enable(Trace(clock=clock))
    try:
        yield trace
    finally:
        _ACTIVE = prev
        if path is not None:
            trace.export_jsonl(path)
