from repro.serve.generate import Generator
from repro.serve.anticluster_service import AnticlusterService

__all__ = ["Generator", "AnticlusterService"]
