from repro.serve.generate import Generator
from repro.serve.router import (AnticlusterRouter, EnginePool, Rejected,
                                ServiceMetrics, Ticket)
from repro.serve.anticluster_service import AnticlusterService

__all__ = ["AnticlusterRouter", "AnticlusterService", "EnginePool",
           "Generator", "Rejected", "ServiceMetrics", "Ticket"]
