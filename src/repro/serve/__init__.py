from repro.serve.generate import Generator

__all__ = ["Generator"]
