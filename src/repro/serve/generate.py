"""Batched generation engine: prefill once, then jit'd decode steps.

Static-batch serving (all requests share a step clock); the KV cache layout
and shardings come from transformer.cache_defs, so the same engine lowers on
the production mesh (decode_32k / long_500k dry-run cells) and runs reduced
configs on CPU for the examples/tests.  Sampling: greedy or temperature.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


class Generator:
    def __init__(self, cfg, params, *, mesh=None, max_len: int = 512):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.max_len = max_len
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg, mesh))

    @staticmethod
    def _decode_impl(cfg, mesh, params, cache, kv_len, tokens, key, temp):
        logits, cache = T.decode_step(cfg, params, cache, kv_len, tokens,
                                      mesh=mesh)
        last = logits[:, -1, :]
        greedy = jnp.argmax(last, axis=-1)
        sampled = jax.random.categorical(key, last / jnp.maximum(temp, 1e-6))
        nxt = jnp.where(temp > 0, sampled, greedy).astype(jnp.int32)
        return nxt[:, None], cache

    def generate(self, prompts: np.ndarray, n_steps: int, *,
                 temperature: float = 0.0, seed: int = 0,
                 enc_frames=None, extra_embeds=None,
                 stop_token: int | None = None) -> np.ndarray:
        """prompts: (B, S_prompt) int32.  Returns (B, n_steps) tokens."""
        cfg = self.cfg
        prompts = jnp.asarray(prompts)
        b, s = prompts.shape
        assert s + n_steps <= self.max_len, "increase max_len"
        logits, cache = T.prefill(cfg, self.params, prompts, self.max_len,
                                  mesh=self.mesh, enc_frames=enc_frames,
                                  extra_embeds=extra_embeds)
        kv_len = jnp.int32(s)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(seed)
        out = [tok]
        done = np.zeros(b, bool)
        for i in range(n_steps - 1):
            key, sub = jax.random.split(key)
            tok, cache = self._decode(self.params, cache, kv_len, tok, sub,
                                      jnp.float32(temperature))
            kv_len = kv_len + 1
            out.append(tok)
            if stop_token is not None:
                done |= np.asarray(tok[:, 0]) == stop_token
                if done.all():
                    break
        return np.concatenate([np.asarray(t) for t in out], axis=1)
