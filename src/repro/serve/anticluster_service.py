"""Synchronous facade over the async serving tier.

:class:`AnticlusterService` is the PR-4 surface -- ``partition`` for one
request, ``partition_many`` for a burst -- kept bit-for-bit compatible but
now a thin wrapper over :class:`repro.serve.router.AnticlusterRouter`:
``partition_many`` admits the whole burst atomically and drives the queue
inline (no background thread), so same-bucket requests stack exactly as the
old service stacked same-shape bursts, with the router's row-bucket
padding, engine pools, and metrics riding along for free.

New code should use the router's async surface directly
(``submit(x, deadline=...) -> Ticket``); this class exists so no caller
migrates under duress.
"""

from __future__ import annotations

from repro.serve.router import AnticlusterRouter

__all__ = ["AnticlusterService"]


class AnticlusterService(AnticlusterRouter):
    """Shape-keyed, warm-started request batching for ``anticluster``.

    A :class:`repro.serve.router.AnticlusterRouter` with no background
    worker: callers drive the queue inline through the synchronous
    ``partition`` / ``partition_many`` (or explicitly via ``submit`` +
    ``Ticket.result``, which pumps the queue on the calling thread).
    Single-threaded and deterministic -- the shape tier-1 tests and
    library embeddings want; services absorbing live traffic should use
    :class:`AnticlusterRouter` itself (``background=True``).
    """

    def __init__(self, spec=None, *, max_group: int = 32, **overrides):
        super().__init__(spec, max_group=max_group, background=False,
                         **overrides)
