"""Request-batching anticlustering service over warm engine lanes.

The serving shape of the paper's repeated-workload story: clients submit
``(n, d)`` feature matrices (``partition`` for one, ``partition_many`` for a
burst) and the service answers with :class:`AnticlusterResult` per request.
Internally requests are grouped by input signature into **lanes**; each lane
owns one :class:`repro.anticluster.AnticlusterEngine` plus its carried
:class:`ABAState`, so a lane compiles on its first request and every later
request warm-starts the auction from the previous traffic's prices --
steady-state serving never retraces and never cold-solves.

Same-shape requests arriving together are additionally *stacked* into one
``(G, M, D)`` batch and solved by a single rank-polymorphic core call (the
ABA core's group axis; flat-plan specs only -- hierarchical specs fall back
to sequential warm calls on the same lane).  Stacked lanes pad the group
axis to power-of-two buckets (repeating the last request) so a fluctuating
burst size maps onto a handful of compiled executables instead of one per
burst width.

A spec with a ``mesh`` serves **sharded warm lanes**: each lane's engine
compiles one ``shard_map`` executable and carries a
:class:`repro.anticluster.ShardedABAState` (per-shard auction prices) across
requests, so steady-state distributed serving warm-starts shard-locally
with zero retraces.  Mesh lanes solve requests one at a time (the group
axis and the shard axis are different placement dims -- stacking is the
single-device batching story), so ``mesh`` composes with everything except
the stacked bucket path.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.anticluster import (ABAState, AnticlusterEngine,
                               AnticlusterResult, AnticlusterSpec)

__all__ = ["AnticlusterService"]


@dataclasses.dataclass
class _Lane:
    engine: AnticlusterEngine
    state: ABAState | None = None


class AnticlusterService:
    """Shape-keyed, warm-started request batching for ``anticluster``.

    Args:
      spec: the :class:`AnticlusterSpec` every request is solved under
        (keyword ``overrides`` compose like ``anticluster``'s).  Specs with
        ``categories`` / ``valid_mask`` are per-dataset rather than
        per-request concepts and are rejected here; a ``mesh`` spec serves
        each request distributed on warm sharded lanes (requests then solve
        sequentially per lane -- no stacking across the group axis).
      max_group: cap on the stacked group axis; bursts larger than this are
        split into successive stacked calls.
    """

    def __init__(self, spec: AnticlusterSpec | None = None, *,
                 max_group: int = 32, **overrides):
        if spec is None:
            spec = AnticlusterSpec(**overrides)
        elif overrides:
            spec = spec.replace(**overrides)
        if spec.categories is not None or spec.valid_mask is not None:
            raise NotImplementedError(
                "AnticlusterService serves anonymous flat (n, d) requests; "
                "categories/valid_mask are per-dataset concepts -- use "
                "AnticlusterEngine directly")
        if max_group < 1:
            raise ValueError(f"max_group={max_group} must be >= 1")
        self.spec = spec
        self.max_group = max_group
        self._lanes: dict = {}
        # stacked (G, M, D) execution needs a flat per-request plan (and no
        # mesh: the shard axis is placement, the group axis is batching);
        # the factorization search is static per spec, so resolve once here
        self._flat_plan = (len(spec.resolve_plan()) == 1
                           and spec.mesh is None)

    @property
    def lane_count(self) -> int:
        """Number of live (engine, state) lanes -- one per input signature."""
        return len(self._lanes)

    def _lane(self, key) -> _Lane:
        lane = self._lanes.get(key)
        if lane is None:
            lane = _Lane(engine=AnticlusterEngine(self.spec))
            self._lanes[key] = lane
        return lane

    def _can_stack(self, shape) -> bool:
        return self._flat_plan and len(shape) == 2

    def partition(self, x) -> AnticlusterResult:
        """Serve one request on its (warm) lane."""
        return self.partition_many([x])[0]

    def partition_many(self, requests) -> list[AnticlusterResult]:
        """Serve a burst; results align with the request order.

        Same-shape requests are stacked into (G, M, D) engine calls in
        power-of-two group buckets; each bucket size is its own lane (own
        compiled executable + carried prices).
        """
        xs = [jnp.asarray(x).astype(self.spec.dtype) for x in requests]
        groups: dict[tuple, list[int]] = {}
        for i, x in enumerate(xs):
            groups.setdefault(tuple(x.shape), []).append(i)
        results: list = [None] * len(xs)
        for shape, idxs in groups.items():
            solo = idxs
            if len(idxs) > 1 and self._can_stack(shape):
                solo = []
                for lo in range(0, len(idxs), self.max_group):
                    part = idxs[lo:lo + self.max_group]
                    if len(part) == 1:
                        solo.extend(part)  # burst remainders of 1 go to the
                        continue           # solo lane for this signature
                    self._serve_stacked(xs, part, shape, results)
            lane = self._lane(("solo", shape)) if solo else None
            for i in solo:
                res, state = self._call(lane, xs[i])
                lane.state = state
                results[i] = res
        return results

    def _serve_stacked(self, xs, idxs, shape, results):
        G = len(idxs)
        bucket = 1 << (G - 1).bit_length()  # pad bursts to pow2 widths
        stack = jnp.stack([xs[i] for i in idxs]
                          + [xs[idxs[-1]]] * (bucket - G))
        lane = self._lane(("stack", shape, bucket))
        res, state = self._call(lane, stack)
        lane.state = state
        for g, i in enumerate(idxs):
            results[i] = AnticlusterResult(
                labels=res.labels[g], cluster_sizes=res.cluster_sizes[g],
                diversity_sd=res.diversity_sd[g],
                diversity_range=res.diversity_range[g],
                k=res.k, plan=res.plan, solver=res.solver,
                variant=res.variant)

    def _call(self, lane: _Lane, x):
        if lane.state is None:
            return lane.engine.partition(x)
        return lane.engine.repartition(x, lane.state)
