"""Async anticlustering serving tier: continuous batching over engine pools.

The production shape of the paper's repeated-workload story
(:class:`AnticlusterRouter`): clients ``submit`` ``(n, d)`` feature
matrices and get a :class:`Ticket` back; a bounded admission queue feeds
**continuous batching** -- pending requests are admitted into the *next*
in-flight stacked lane call instead of only stacking bursts that happen to
arrive together (the PR-4 synchronous service's limitation).

Admission groups requests three ways:

* **Row buckets.**  Requests whose row counts land in the same
  power-of-two bucket are padded to the bucket with a per-call
  ``valid_mask`` (the first real exercise of the engine's uneven-row
  masking), so near-shapes share ONE compiled lane executable instead of
  one per distinct ``n``.  Padding is restricted to requests whose
  unpadded solve uses the base (non-interleave) rearrangement -- the
  masked core skips the Section-4.2 interleave, so only there is the
  padded solve bit-for-bit identical to the unpadded one (pinned by
  tests/test_serve.py).  Interleave-regime requests still stack, but only
  with exact shape twins (the pre-padding behaviour).
* **Group buckets.**  A formed batch stacks its requests on the core's
  group axis, padded to a power-of-two width by repeating the last
  request (same as the synchronous service) -- a fluctuating batch size
  maps onto a handful of compiled executables.
* **Sequential lanes.**  Hierarchical-plan and mesh specs cannot stack
  (the group axis needs a flat plan; the mesh uses its own placement
  axis, PR-5 semantics): their requests serve one-at-a-time on warm solo
  lanes.  For hierarchical specs this is a *degraded* path -- it is
  surfaced by the ``degraded_sequential`` metric and a one-time
  ``RuntimeWarning`` instead of silently losing throughput.

Robustness: the queue is bounded (``submit`` raises
:class:`Rejected`("queue_full") -- backpressure, never OOM), requests
carry optional deadlines and are shed at admission when expired
(:class:`Rejected`("deadline")), closing the router rejects pending
work (:class:`Rejected`("shutdown")), and an engine error while serving
resolves the affected tickets with that exception (re-raised by
``Ticket.result``; counted in ``ServiceMetrics.errored``) instead of
killing the worker -- the loop keeps serving.  Throughput: per-spec
:class:`EnginePool` lanes are placed round-robin across ``jax.devices()``
(meshless specs), so concurrent lanes solve on different chips.
Observability: :meth:`AnticlusterRouter.metrics` returns a
:class:`ServiceMetrics` snapshot (queue depth, warm-hit rate, stack/row
occupancy, per-lane compile counts, degraded-path counters);
``benchmarks/serve_bench.py`` turns it into the CI-gated
``BENCH_serve.json`` SLO trajectory.

The synchronous ``partition`` / ``partition_many`` survive as thin
wrappers over ``submit`` (see :class:`repro.serve.AnticlusterService`) --
bit-for-bit identical results, no caller migrates under duress.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.anticluster import (AnticlusterEngine, AnticlusterResult,
                               AnticlusterSpec, _mesh_shards, _resolve_spec)

__all__ = ["AnticlusterRouter", "EnginePool", "Rejected", "ServiceMetrics",
           "Ticket"]

# A request is row-padded only when its unpadded solve would use the base
# rearrangement: variant "auto" interleaves at n // k <= 8 (mirrors
# ``repro.core.aba.aba_core``), and the masked core skips interleave, so
# padding an interleave-regime request would change its labels.
_INTERLEAVE_RATIO = 8


class Rejected(RuntimeError):
    """Typed rejection outcome of a serving request.

    ``reason`` is one of:

    * ``"queue_full"`` -- backpressure: the bounded admission queue was at
      ``max_queue`` (raised synchronously by ``submit``; the request was
      never admitted).  Burst admission via ``partition_many`` is
      all-or-nothing: a burst that does not fit whole is rejected whole,
      and every request in it counts toward
      ``ServiceMetrics.rejected_full``.
    * ``"deadline"`` -- the request's deadline expired before a lane picked
      it up; it was shed at admission and its ticket resolves rejected.
    * ``"shutdown"`` -- the router was closed while the request was pending.
    """

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


class Ticket:
    """Handle for one submitted request.

    ``done()`` is non-blocking; ``result()`` blocks until the request is
    served (re-raising the :class:`Rejected` outcome if it was shed, or
    the engine's exception if serving it errored) -- under a background
    worker it waits, without one it *drives* the router's queue inline,
    so the sync wrappers never need a thread.  ``submitted_at`` /
    ``completed_at`` are router-clock stamps and ``latency`` their
    difference: the load benchmark's SLO numbers come straight from
    tickets.
    """

    __slots__ = ("_router", "_event", "_result", "_rejection", "_error",
                 "submitted_at", "completed_at")

    def __init__(self, router: "AnticlusterRouter", submitted_at: float):
        self._router = router
        self._event = threading.Event()
        self._result: AnticlusterResult | None = None
        self._rejection: Rejected | None = None
        self._error: BaseException | None = None
        self.submitted_at = submitted_at
        self.completed_at: float | None = None

    def done(self) -> bool:
        """True once the request was served or rejected (non-blocking)."""
        return self._event.is_set()

    @property
    def rejection(self) -> Rejected | None:
        """The :class:`Rejected` outcome, or None (pending / served)."""
        return self._rejection

    @property
    def error(self) -> BaseException | None:
        """The exception serving this request raised, or None."""
        return self._error

    @property
    def latency(self) -> float | None:
        """Seconds from submission to completion (None while pending)."""
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def result(self, timeout: float | None = None) -> AnticlusterResult:
        """The request's :class:`AnticlusterResult` (blocks until served).

        Raises the ticket's :class:`Rejected` if the request was shed, the
        engine's exception if serving it errored, and ``TimeoutError`` if
        ``timeout`` seconds pass first.  Without a background worker the
        timeout is best-effort: the calling thread drives the queue and
        only checks the clock between ``step()`` calls, so one step (a
        first-call compile, or a large stacked solve of other requests'
        groups) can overrun the budget before ``TimeoutError`` is raised.
        """
        self._router._fulfil(self, timeout)
        if self._rejection is not None:
            raise self._rejection
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result=None, rejection=None, error=None, at=None):
        self._result = result
        self._rejection = rejection
        self._error = error
        self.completed_at = at
        self._event.set()


@dataclasses.dataclass(frozen=True)
class ServiceMetrics:
    """Point-in-time observability snapshot of a router.

    Counters are lifetime totals since construction; ``queue_depth`` and
    ``lane_compile_counts`` are current.  The derived properties are the
    serving-tier SLO signals: ``warm_hit_rate`` (fraction of lane calls
    that warm-started from carried prices), ``stack_occupancy`` (real
    requests per stacked group slot -- how much of the batching headroom
    traffic actually uses), ``row_occupancy`` (real rows per padded row
    slot -- the cost of row-bucket admission), and ``shed_rate``.
    ``errored`` counts requests whose serve raised (their tickets carry
    the exception); a rejected ``partition_many`` burst adds every one of
    its requests to ``rejected_full``.  The live-partition lane reports
    ``update_calls`` / ``update_fallbacks`` (deltas that fell back to a
    full repartition; ``update_fallback_rate`` derives the ratio -- a
    rising rate means deltas outgrew ``spec.update_threshold``) and the
    current ``live_partitions`` count.
    """

    queue_depth: int
    submitted: int
    completed: int
    shed_deadline: int
    rejected_full: int
    errored: int
    stacked_calls: int
    solo_calls: int
    warm_calls: int
    cold_calls: int
    degraded_sequential: int
    group_slots: int
    group_filled: int
    row_slots: int
    row_filled: int
    lane_compile_counts: dict[str, int]
    devices: int
    # the live-partition (delta-update) lane; defaults keep older
    # positional/partial constructions working
    update_calls: int = 0
    update_fallbacks: int = 0
    live_partitions: int = 0
    # request-latency / queue-wait percentiles (seconds) over the router's
    # retained sample window (``repro.obs.Histogram``); 0.0 before any
    # request completes.  Latency is submit -> ticket resolution; queue
    # wait is submit -> the serve that picked the request up.
    latency_p50: float = 0.0
    latency_p99: float = 0.0
    queue_wait_p50: float = 0.0
    queue_wait_p99: float = 0.0

    @property
    def update_fallback_rate(self) -> float:
        return (self.update_fallbacks / self.update_calls
                if self.update_calls else 0.0)

    @property
    def warm_hit_rate(self) -> float:
        calls = self.warm_calls + self.cold_calls
        return self.warm_calls / calls if calls else 0.0

    @property
    def stack_occupancy(self) -> float:
        return self.group_filled / self.group_slots if self.group_slots \
            else 0.0

    @property
    def row_occupancy(self) -> float:
        return self.row_filled / self.row_slots if self.row_slots else 0.0

    @property
    def shed_rate(self) -> float:
        finished = self.completed + self.shed_deadline
        return self.shed_deadline / finished if finished else 0.0


@dataclasses.dataclass
class _Lane:
    """One warm serving lane: an engine, its carried state, its device."""

    engine: AnticlusterEngine
    state: Any = None
    device: Any = None
    calls: int = 0


class EnginePool:
    """Per-spec pool of warm engine lanes, placed round-robin over devices.

    Each lane key (an input signature bucket) owns one
    :class:`AnticlusterEngine` plus its carried state.  Meshless specs
    place successive *new* lanes on ``jax.devices()`` round-robin -- a
    lane's inputs and state are committed to its device, so lanes solve on
    different chips without any cross-device chatter.  Mesh specs keep the
    PR-5 semantics (the engine's ``shard_map`` placement owns the devices;
    no per-lane pinning).

    ``lane()`` does not lock: the router calls it under its metrics lock,
    which is what lets ``AnticlusterRouter.metrics`` iterate ``lanes``
    concurrently with serving.
    """

    def __init__(self, spec: AnticlusterSpec):
        self.spec = spec
        self.lanes: dict[tuple, _Lane] = {}
        self._devices = list(jax.devices()) if spec.mesh is None else []
        self._next_device = 0

    @property
    def device_count(self) -> int:
        return len(self._devices) if self._devices else len(jax.devices())

    def lane(self, key: tuple) -> _Lane:
        lane = self.lanes.get(key)
        if lane is None:
            device = None
            if len(self._devices) > 1:
                device = self._devices[self._next_device
                                       % len(self._devices)]
                self._next_device += 1
            lane = _Lane(engine=AnticlusterEngine(self.spec), device=device)
            self.lanes[key] = lane
        return lane


@dataclasses.dataclass
class _Request:
    x: Any                      # (n, d) jnp array, already spec.dtype
    n: int
    d: int
    ticket: Ticket
    deadline_at: float | None   # absolute router-clock time, or None
    key: tuple                  # admission key (what can batch together)
    bucket: int                 # padded row count (== n when not padded)
    op: str = "solve"           # "solve" | "open" | "update"
    payload: Any = None         # ("update": the (added, removed) delta)


class AnticlusterRouter:
    """Admission-controlled async front end over warm anticluster lanes.

    Args:
      spec: the :class:`AnticlusterSpec` every request is solved under
        (keyword ``overrides`` compose via ``AnticlusterSpec.evolve``).
        Specs with ``categories`` / ``valid_mask`` are per-dataset rather
        than per-request concepts and are rejected; a ``mesh`` spec serves
        requests one-at-a-time on warm sharded lanes (PR-5 semantics).
      max_group: cap on the stacked group axis per lane call; pending
        same-bucket requests beyond it wait for the next call.
      max_queue: bound on admitted-but-unserved requests; ``submit`` above
        it raises :class:`Rejected`("queue_full") synchronously.
      row_buckets: pad near-shapes to power-of-two row buckets so they
        share lanes (False restores exact-shape-only stacking).
      background: serve from a daemon worker thread (started lazily on the
        first ``submit``).  False leaves driving to the caller:
        ``Ticket.result`` / ``drain`` / ``step`` pump the queue inline --
        deterministic and thread-free, which is what the sync
        :class:`repro.serve.AnticlusterService` wrapper and the tier-1
        tests use.
      clock: the router's time source (monotonic seconds) for deadlines
        and latency stamps; injectable so tests shed deterministically.
    """

    def __init__(self, spec: AnticlusterSpec | None = None, *,
                 max_group: int = 32, max_queue: int = 1024,
                 row_buckets: bool = True, background: bool = True,
                 clock: Callable[[], float] = time.monotonic, **overrides):
        spec = _resolve_spec(spec, overrides)
        if spec.categories is not None or spec.fairness is not None \
                or spec.valid_mask is not None:
            raise NotImplementedError(
                "the serving tier solves anonymous flat (n, d) requests; "
                "categories/fairness/valid_mask are per-dataset concepts -- "
                "use AnticlusterEngine directly")
        if max_group < 1:
            raise ValueError(f"max_group={max_group} must be >= 1")
        if max_queue < 1:
            raise ValueError(f"max_queue={max_queue} must be >= 1")
        self.spec = spec
        self.max_group = max_group
        self.max_queue = max_queue
        self.row_buckets = row_buckets
        self._clock = clock
        self._background = background
        self._plan = spec.resolve_plan()
        # stacked (G, M, D) execution needs a flat per-request plan, no mesh
        # (the shard axis is placement, the group axis is batching), and a
        # dense solve (an explicit int chunk_size bans stacked input)
        self._stackable = (len(self._plan) == 1 and spec.mesh is None
                           and not isinstance(spec.chunk_size, int))
        self._is_hier = len(self._plan) > 1 and spec.mesh is None
        self._shards = _mesh_shards(spec)  # 1 when meshless
        self._pool = EnginePool(spec)
        self._queue: collections.deque[_Request] = collections.deque()
        self._cv = threading.Condition()
        self._serve_mutex = threading.Lock()  # one batch former at a time
        self._worker: threading.Thread | None = None
        self._closed = False
        self._warned_degraded = False
        # metrics counters (guarded by self._cv)
        self._submitted = 0
        self._completed = 0
        self._shed_deadline = 0
        self._rejected_full = 0
        self._errored = 0
        self._stacked_calls = 0
        self._solo_calls = 0
        self._warm_calls = 0
        self._cold_calls = 0
        self._degraded_sequential = 0
        self._group_slots = 0
        self._group_filled = 0
        self._row_slots = 0
        self._row_filled = 0
        self._update_calls = 0
        self._update_fallbacks = 0
        # latency/queue-wait reservoirs: internally locked, recorded outside
        # self._cv (histogram recording must not lengthen the metrics lock)
        self._lat_hist = obs.Histogram()
        self._qwait_hist = obs.Histogram()
        # live named partitions (the delta-update lane).  _live_names is
        # the synchronous reservation set (admission-time duplicate/unknown
        # checks); _live maps name -> IncrementalPartition once the open
        # has been served.  Both guarded by self._cv; the partitions
        # themselves are only touched under _serve_mutex.
        self._live_names: set[str] = set()
        self._live: dict[str, Any] = {}

    # -- admission ----------------------------------------------------------

    @property
    def lane_count(self) -> int:
        """Number of live (engine, state) lanes -- one per signature bucket."""
        return len(self._pool.lanes)

    @property
    def _lanes(self) -> dict:
        return self._pool.lanes

    def _coerce(self, x) -> jnp.ndarray:
        xa = jnp.asarray(x)
        if xa.ndim != 2:
            raise ValueError(
                f"requests are (n, d) feature matrices; got shape "
                f"{tuple(xa.shape)}")
        if xa.shape[0] < self.spec.k:
            raise ValueError(
                f"request has n={xa.shape[0]} rows < spec.k={self.spec.k}")
        if self._shards > 1 and xa.shape[0] % self._shards \
                and len(self._plan) > 1:
            # flat per-shard plans auto-pad uneven rows inside the engine
            # (masked zero rows; see AnticlusterEngine._solve_shape), so
            # only the composition the engine itself cannot mask -- a
            # multi-level per-shard plan -- is rejected here, at admission:
            # by the time a lane solves, the ticket is the only way out,
            # and an async failure is a worse surface than a synchronous one
            raise ValueError(
                f"request has n={xa.shape[0]} rows, not divisible by the "
                f"mesh shard count {self._shards}, and the per-shard plan "
                f"{self._plan} is hierarchical (mesh lanes can auto-pad "
                "uneven rows only under a flat per-shard plan; raise "
                "max_k or pad the request)")
        return xa.astype(self.spec.dtype)

    def _admission(self, n: int, d: int) -> tuple[tuple, int]:
        """(admission key, padded row bucket) for an ``(n, d)`` request.

        Requests sharing a key may be served by one stacked lane call.
        """
        if not self._stackable:
            return ("seq", n, d), n
        spec = self.spec
        paddable = self.row_buckets and (
            spec.variant == "base"
            or (spec.variant == "auto" and n // spec.k > _INTERLEAVE_RATIO))
        if paddable:
            bucket = 1 << (n - 1).bit_length()  # next pow2 >= n
            if spec.resolve_chunk(bucket, self._plan[0]) is not None:
                # at streaming scale the flat chunked path beats a padded
                # dense stack; serve solo (the solo lane streams)
                return ("seq", n, d), n
            return ("pad", bucket, d), bucket
        return ("exact", n, d), n

    def submit(self, x, deadline: float | None = None) -> Ticket:
        """Admit one ``(n, d)`` request; returns its :class:`Ticket`.

        ``deadline`` is a seconds-from-now latency budget: a request still
        queued when it expires is shed (its ticket resolves
        :class:`Rejected`("deadline")).  Raises
        :class:`Rejected`("queue_full") synchronously when the bounded
        queue is full -- backpressure, by construction never OOM.
        """
        xa = self._coerce(x)
        with self._cv:
            return self._submit_locked(xa, deadline)

    def _submit_locked(self, xa, deadline: float | None, *,
                       op: str = "solve", key: tuple | None = None,
                       payload: Any = None) -> Ticket:
        if self._closed:
            raise Rejected("shutdown")
        if len(self._queue) >= self.max_queue:
            self._rejected_full += 1
            raise Rejected("queue_full")
        now = self._clock()
        n, d = map(int, xa.shape) if xa is not None else (0, 0)
        if key is None:
            key, bucket = self._admission(n, d)
        else:
            bucket = n
        ticket = Ticket(self, now)
        self._queue.append(_Request(
            x=xa, n=n, d=d, ticket=ticket,
            deadline_at=None if deadline is None else now + deadline,
            key=key, bucket=bucket, op=op, payload=payload))
        self._submitted += 1
        obs.event("serve/admit", n=n, d=d, op=op,
                  queue_depth=len(self._queue))
        if self._background and (self._worker is None
                                 or not self._worker.is_alive()):
            self._worker = threading.Thread(
                target=self._worker_loop, name="anticluster-router",
                daemon=True)
            self._worker.start()
        self._cv.notify()
        return ticket

    # -- sync wrappers (the PR-4 service surface, now thin) -----------------

    def partition(self, x) -> AnticlusterResult:
        """Serve one request synchronously: ``submit(x).result()``."""
        return self.submit(x).result()

    def partition_many(self, requests) -> list[AnticlusterResult]:
        """Serve a burst synchronously; results align with request order.

        Admission is atomic -- every request enters the queue before any
        batch is formed -- so batching is deterministic: same-bucket
        requests stack together exactly as the old synchronous service
        stacked same-shape bursts (continuous batching then extends the
        same behaviour to requests that arrive *while* a call is in
        flight).
        """
        xs = [self._coerce(x) for x in requests]
        with self._cv:
            if len(xs) + len(self._queue) > self.max_queue:
                self._rejected_full += len(xs)  # every request of the burst
                raise Rejected("queue_full")
            tickets = [self._submit_locked(xa, None) for xa in xs]
        return [t.result() for t in tickets]

    # -- live partitions (the delta-update lane) -----------------------------

    def open_partition(self, name: str, x,
                       deadline: float | None = None) -> Ticket:
        """Admit ``x`` as the named *live* partition; returns its Ticket.

        A live partition stays resident after its solve: subsequent
        :meth:`submit_update` calls absorb row arrivals/departures through
        :meth:`repro.anticluster.AnticlusterEngine.update` instead of
        re-solving.  The name is reserved synchronously (a duplicate
        ``open_partition`` raises ``ValueError`` immediately, not on the
        ticket); open and update ops on one name share the admission key
        ``("update", name)``, so the queue's FIFO order IS the partition's
        op order.  Mesh specs have no delta path and raise here.
        """
        if self.spec.mesh is not None:
            raise NotImplementedError(
                "mesh lanes do not support delta updates; submit() full "
                "requests instead")
        xa = self._coerce(x)
        with self._cv:
            if name in self._live_names:
                raise ValueError(
                    f"live partition {name!r} is already open")
            ticket = self._submit_locked(xa, deadline, op="open",
                                         key=("update", name))
            self._live_names.add(name)
            return ticket

    def submit_update(self, name: str, added=None, removed=None,
                      deadline: float | None = None) -> Ticket:
        """Admit a delta against the named live partition.

        ``added`` is an (m, d) block of arriving rows; ``removed`` names
        departing rows of the partition's *current* row order (int indices
        or a bool mask) -- :meth:`AnticlusterEngine.update` semantics,
        including the loud over-threshold fallback (``result.updated`` is
        False for that call and ``ServiceMetrics.update_fallbacks``
        counts it).  Raises ``ValueError`` synchronously when ``name`` was
        never opened (or already closed).
        """
        with self._cv:
            if name not in self._live_names:
                raise ValueError(
                    f"live partition {name!r} is not open (open_partition "
                    "first)")
            added_a = (None if added is None
                       else jnp.asarray(added).astype(self.spec.dtype))
            return self._submit_locked(None, deadline, op="update",
                                       key=("update", name),
                                       payload=(added_a, removed))

    def live_partition(self, name: str):
        """The named :class:`repro.incremental.IncrementalPartition`.

        Available once the open ticket resolved; ``KeyError`` otherwise.
        Treat it as read-only (``.labels``, ``.x``, ``.result``) -- mutate
        through :meth:`submit_update`, which serializes with serving.
        """
        with self._cv:
            part = self._live.get(name)
        if part is None:
            raise KeyError(
                f"live partition {name!r} is not open (or its open has "
                "not been served yet)")
        return part

    def partition_labels(self, name: str):
        """Current labels of the named live partition (see live_partition)."""
        return self.live_partition(name).labels

    def close_partition(self, name: str) -> None:
        """Release the named live partition (its name becomes reusable).

        Updates still queued for it resolve with an error; drain first for
        a clean shutdown of the name.
        """
        with self._cv:
            self._live_names.discard(name)
            self._live.pop(name, None)

    # -- serving ------------------------------------------------------------

    def step(self) -> bool:
        """Form and serve one admission group; False when the queue is idle.

        The worker thread's unit of work, public so callers without a
        background worker (tests, the sync wrappers) can drive the queue
        deterministically.  A group's requests are popped from the queue
        before serving, so an engine error must not escape with their
        tickets unresolved: it is caught here, the group's pending tickets
        resolve with the exception (``Ticket.result`` re-raises it,
        ``ServiceMetrics.errored`` counts it), and the worker loop keeps
        serving.
        """
        with self._serve_mutex:
            with self._cv:
                group = self._take_group_locked()
            if group is None:
                return False
            try:
                self._serve(group)
            except Exception as exc:
                now = self._clock()
                pending = [r for r in group if not r.ticket.done()]
                with self._cv:
                    self._errored += len(pending)
                for r in pending:
                    r.ticket._resolve(error=exc, at=now)
            return True

    def drain(self) -> None:
        """Serve until the queue is empty (inline; safe alongside a worker)."""
        while self.step():
            pass

    def _fulfil(self, ticket: Ticket, timeout: float | None) -> None:
        if ticket.done():
            return
        worker = self._worker
        if worker is not None and worker.is_alive() \
                and worker is not threading.current_thread():
            if not ticket._event.wait(timeout):
                raise TimeoutError(
                    f"request not served within {timeout} s")
            return
        stop_at = None if timeout is None else time.monotonic() + timeout
        while not ticket.done():
            # best-effort: checked before every step, but a single step
            # (first-call compile, someone else's large stacked group) can
            # overrun the budget -- see Ticket.result
            if stop_at is not None and time.monotonic() > stop_at:
                raise TimeoutError(f"request not served within {timeout} s")
            if not self.step():
                if ticket.done():
                    return
                raise RuntimeError(
                    "ticket is unresolved but the queue is idle (router "
                    "closed?)")

    def _take_group_locked(self) -> list[_Request] | None:
        """Shed expired requests, then pop the head's admission group."""
        now = self._clock()
        kept: collections.deque[_Request] = collections.deque()
        while self._queue:
            r = self._queue.popleft()
            if r.deadline_at is not None and now > r.deadline_at:
                self._shed_deadline += 1
                r.ticket._resolve(rejection=Rejected("deadline"), at=now)
            else:
                kept.append(r)
        self._queue = kept
        if not self._queue:
            return None
        head = self._queue.popleft()
        group = [head]
        rest: collections.deque[_Request] = collections.deque()
        while self._queue:
            r = self._queue.popleft()
            if r.key == head.key and len(group) < self.max_group:
                group.append(r)
            else:
                rest.append(r)
        self._queue = rest
        if len(group) > 1 and head.key[0] == "seq" and self._is_hier:
            self._degraded_sequential += len(group)
            if not self._warned_degraded:
                self._warned_degraded = True
                warnings.warn(
                    f"hierarchical plan {self._plan} cannot stack requests "
                    "on the group axis: a burst of "
                    f"{len(group)} same-shape requests degrades to "
                    "sequential warm solves (counted in "
                    "ServiceMetrics.degraded_sequential; use a flat plan "
                    "-- plan=None or max_k >= k -- for stacked serving)",
                    RuntimeWarning, stacklevel=3)
        return group

    def _serve(self, group: list[_Request]) -> None:
        now = self._clock()
        for r in group:
            wait = now - r.ticket.submitted_at
            self._qwait_hist.record(wait)
            obs.event("serve/queue_wait", wait=wait, n=r.n)
        head = group[0]
        if head.key[0] == "update":
            # one live partition's ops, in FIFO order (the admission key
            # pins the name, _take_group_locked keeps arrival order)
            for r in group:
                self._serve_live(r)
            return
        if head.key[0] == "seq":
            for r in group:
                self._serve_solo(r)
            return
        if len(group) == 1 and head.n == head.bucket:
            # an exact-fit singleton takes the plain flat lane (identical
            # labels either way; keeps single-stream traffic off the
            # stacked executables)
            self._serve_solo(head)
            return
        self._serve_stacked(group)

    def _resolve_served(self, r: "_Request", result, at: float) -> None:
        """Resolve a served ticket, recording its end-to-end latency."""
        self._lat_hist.record(at - r.ticket.submitted_at)
        r.ticket._resolve(result=result, at=at)

    def _serve_live(self, r: _Request) -> None:
        """Apply one live-partition op (runs under ``_serve_mutex``).

        An exception (unknown name after close, a bad delta shape) escapes
        to ``step``, which resolves the ticket with it and counts it in
        ``errored`` -- same containment as every other serve path.
        """
        from repro.incremental import IncrementalPartition
        name = r.key[1]
        if r.op == "open":
            with self._cv:
                lane = self._pool.lane(("live", name))
            x = r.x
            if lane.device is not None:
                x = jax.device_put(x, lane.device)
            part = IncrementalPartition(x, engine=lane.engine)
            lane.calls += 1
            with self._cv:
                self._live[name] = part
                self._cold_calls += 1
                self._solo_calls += 1
                self._completed += 1
            self._resolve_served(r, part.result, self._clock())
            return
        with self._cv:
            part = self._live.get(name)
        if part is None:
            raise KeyError(
                f"live partition {name!r} was closed (or its open "
                "errored) before this update was served")
        added, removed = r.payload
        with obs.span("serve/update", partition=name):
            res = part.update(added=added, removed=removed)
        with self._cv:
            self._update_calls += 1
            if not res.updated:
                self._update_fallbacks += 1
            self._completed += 1
        self._resolve_served(r, res, self._clock())

    def _serve_solo(self, r: _Request) -> None:
        res, _warm = self._call_lane(("solo", (r.n, r.d)), r.x, None)
        with self._cv:
            self._solo_calls += 1
            self._completed += 1
        self._resolve_served(r, res, self._clock())

    def _serve_stacked(self, group: list[_Request]) -> None:
        head = group[0]
        G, rows, d = len(group), head.bucket, head.d
        gbucket = 1 << (G - 1).bit_length()  # pad bursts to pow2 widths
        dtype = self.spec.dtype
        xs = [r.x if r.n == rows
              else jnp.concatenate(
                  [r.x, jnp.zeros((rows - r.n, d), dtype)], axis=0)
              for r in group]
        xs += [xs[-1]] * (gbucket - G)
        stack = jnp.stack(xs)
        vm = None
        if any(r.n < rows for r in group):
            m = np.zeros((gbucket, rows), np.bool_)
            for g, r in enumerate(group):
                m[g, :r.n] = True
            m[G:] = m[G - 1]  # group-padding repeats the last request
            vm = jnp.asarray(m)
        res, _warm = self._call_lane(("stack", (rows, d), gbucket), stack, vm)
        with self._cv:
            self._stacked_calls += 1
            self._completed += len(group)
            self._group_slots += gbucket
            self._group_filled += G
            self._row_slots += G * rows
            self._row_filled += sum(r.n for r in group)
        now = self._clock()
        for g, r in enumerate(group):
            self._resolve_served(r, AnticlusterResult(
                labels=res.labels[g][:r.n],
                cluster_sizes=res.cluster_sizes[g],
                diversity_sd=res.diversity_sd[g],
                diversity_range=res.diversity_range[g],
                k=res.k, plan=res.plan, solver=res.solver,
                variant=res.variant,
                dual_bound=None if res.dual_bound is None
                else res.dual_bound[g],
                gap=None if res.gap is None else res.gap[g]), now)

    def _call_lane(self, key: tuple, x, vm):
        with self._cv:
            # lane insertion mutates the pool's dict under the same lock
            # metrics() iterates it with (engine construction is cheap --
            # compilation happens in repartition, outside the lock)
            lane = self._pool.lane(key)
        if lane.device is not None:
            x = jax.device_put(x, lane.device)
            if vm is not None:
                vm = jax.device_put(vm, lane.device)
        warm = lane.state is not None
        state = lane.state
        if state is None:
            state = lane.engine.init_state(tuple(x.shape))
            if lane.device is not None:
                state = jax.device_put(state, lane.device)
        with obs.span("serve/solve", lane=str(key), warm=warm):
            res, lane.state = lane.engine.repartition(x, state,
                                                      valid_mask=vm)
        lane.calls += 1
        with self._cv:
            if warm:
                self._warm_calls += 1
            else:
                self._cold_calls += 1
        return res, warm

    # -- lifecycle ----------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if self._closed and not self._queue:
                    return
            while self.step():
                pass

    def close(self) -> None:
        """Stop serving: reject pending requests with Rejected("shutdown")."""
        with self._cv:
            self._closed = True
            now = self._clock()
            while self._queue:
                r = self._queue.popleft()
                r.ticket._resolve(rejection=Rejected("shutdown"), at=now)
            self._cv.notify_all()
        worker, self._worker = self._worker, None
        if worker is not None and worker.is_alive() \
                and worker is not threading.current_thread():
            worker.join(timeout=60.0)

    def __enter__(self) -> "AnticlusterRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- observability ------------------------------------------------------

    def metrics(self) -> ServiceMetrics:
        """A :class:`ServiceMetrics` snapshot (thread-safe)."""
        with self._cv:
            return ServiceMetrics(
                queue_depth=len(self._queue),
                submitted=self._submitted,
                completed=self._completed,
                shed_deadline=self._shed_deadline,
                rejected_full=self._rejected_full,
                errored=self._errored,
                stacked_calls=self._stacked_calls,
                solo_calls=self._solo_calls,
                warm_calls=self._warm_calls,
                cold_calls=self._cold_calls,
                degraded_sequential=self._degraded_sequential,
                group_slots=self._group_slots,
                group_filled=self._group_filled,
                row_slots=self._row_slots,
                row_filled=self._row_filled,
                lane_compile_counts={
                    str(k): lane.engine.compile_count
                    for k, lane in self._pool.lanes.items()},
                devices=self._pool.device_count,
                update_calls=self._update_calls,
                update_fallbacks=self._update_fallbacks,
                live_partitions=len(self._live),
                latency_p50=self._lat_hist.percentile(50),
                latency_p99=self._lat_hist.percentile(99),
                queue_wait_p50=self._qwait_hist.percentile(50),
                queue_wait_p99=self._qwait_hist.percentile(99))
