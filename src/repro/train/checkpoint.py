"""Checkpoint save/restore with atomic rename, retention, and *resharding*
restore (elastic scaling: restore onto a different mesh / dp width).

Format: one .npz per checkpoint step holding flattened path->array leaves +
a JSON manifest (step, tree paths, shapes, dtypes, rng).  Single-process
container writes full arrays; on a real multi-host pod each process would
save only addressable shards (jax.experimental.multihost_utils) -- the
directory layout and manifest already carry everything needed for that
(see launch/train.py fault-tolerance notes).
"""

from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): leaf for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the newest ``keep`` checkpoints."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def restore(ckpt_dir: str, like_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of NamedShardings -- arrays are
    device_put with them, which is exactly resharding onto a new mesh
    (elastic restart with a different dp width / device count).
    Returns (tree, step) or (None, -1) when no checkpoint exists.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        return None, -1
    step = step if step is not None else max(steps)
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_paths = list(_flatten(like_tree).keys())
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    arrays = []
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_paths))
    for key, like, sh in zip(flat_paths, leaves_like, sh_flat):
        a = data[key]
        assert tuple(a.shape) == tuple(like.shape), (key, a.shape, like.shape)
        a = a.astype(like.dtype)
        arrays.append(jax.device_put(a, sh) if sh is not None else
                      jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, arrays), step
