"""Checkpoint save/restore with atomic rename, retention, and *resharding*
restore (elastic scaling: restore onto a different mesh / dp width).

Format: one .npz per checkpoint step holding flattened path->array leaves +
a JSON manifest (step, tree paths, shapes, dtypes, rng).  Single-process
container writes full arrays; on a real multi-host pod each process would
save only addressable shards (jax.experimental.multihost_utils) -- the
directory layout and manifest already carry everything needed for that
(see launch/train.py fault-tolerance notes).
"""

from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    # DictKey has .key, SequenceKey .idx, dataclass GetAttrKey .name
    return {"/".join(str(getattr(k, "key",
                                 getattr(k, "idx", getattr(k, "name", k))))
                     for k in path): leaf for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the newest ``keep`` checkpoints."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def restore(ckpt_dir: str, like_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of NamedShardings -- arrays are
    device_put with them, which is exactly resharding onto a new mesh
    (elastic restart with a different dp width / device count).
    Returns (tree, step) or (None, -1) when no checkpoint exists.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        return None, -1
    step = step if step is not None else max(steps)
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_paths = list(_flatten(like_tree).keys())
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    arrays = []
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_paths))
    for key, like, sh in zip(flat_paths, leaves_like, sh_flat):
        a = data[key]
        assert tuple(a.shape) == tuple(like.shape), (key, a.shape, like.shape)
        a = a.astype(like.dtype)
        arrays.append(jax.device_put(a, sh) if sh is not None else
                      jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, arrays), step


# --- anticlustering engine sessions ----------------------------------------
#
# The engine's carried state (repro.anticluster.ABAState / ShardedABAState)
# is a plain pytree of arrays, so the generic save/restore machinery above
# already handles it; these wrappers add the session ergonomics -- the
# like-tree comes from the engine itself (``init_state``) and a sharded
# session restores straight onto its mesh layout (``state_shardings``), so a
# training job resuming after preemption warm-starts its per-epoch
# anticlustering exactly where it left off instead of cold-solving epoch 0.

def save_engine_state(ckpt_dir: str, step: int, state, *,
                      keep: int = 3) -> str:
    """Checkpoint an engine session state (``ABAState``/``ShardedABAState``).

    Sharded states are gathered to host arrays by the generic writer (the
    single-process layout; a multi-host pod would write addressable shards,
    see module docstring).  Restore with :func:`restore_engine_state`.
    """
    return save(ckpt_dir, step, jax.device_get(state), keep=keep)


def restore_engine_state(ckpt_dir: str, engine, x_or_shape, *,
                         step: int | None = None):
    """Restore a session state for ``engine`` and input shape ``x_or_shape``.

    ``engine`` is a ``repro.anticluster.AnticlusterEngine`` (duck-typed:
    anything with ``init_state``/``state_shardings``); the restored arrays
    are validated against its zeroed state and, for mesh specs, placed with
    the engine's ``NamedSharding`` layout -- restoring onto a *different*
    mesh than the one that saved is exactly the elastic-resharding story of
    :func:`restore`, and works as long as the shard count (and therefore
    the state shapes) matches.  Returns ``(state, step)`` or ``(None, -1)``
    when no checkpoint exists.
    """
    like = engine.init_state(x_or_shape)
    return restore(ckpt_dir, like,
                   step=step, shardings=engine.state_shardings(x_or_shape))
