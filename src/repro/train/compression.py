"""Error-feedback int8 gradient compression over the data-parallel axes.

For bandwidth-constrained inter-pod links: the gradient all-reduce is
decomposed into reduce-scatter + all-gather with both legs carried in int8
(per-leaf fp32 scales; the reduce accumulates in int32 -- the conservative
wire model, real ICI reducers keep int8 on the wire).  Quantization error is
kept in an error-feedback state and re-injected next step, preserving SGD
convergence (Karimireddy et al. 2019).

Two entry points:
  * ``ef_allreduce(grads, err, axis_names)`` -- tree op, call INSIDE a
    shard_map whose mesh carries the dp axes.
  * ``make_compressed_dp_train_step(cfg, mesh, opt_cfg)`` -- full replicated-
    model data-parallel train step (per-shard grads -> compressed mean ->
    AdamW), used by launch/train.py --grad-compression and the tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.models import transformer as T
from repro.train.optimizer import OptConfig, adamw_update


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf(g, err, axis_names):
    """int8 error-feedback all-reduce-mean of one leaf."""
    n_dev = 1
    for a in axis_names:
        n_dev *= jax.lax.axis_size(a)
    g = g.astype(jnp.float32) + err
    size = g.size
    flat = g.reshape(-1)
    pad = (-size) % n_dev
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # the scale must be AGREED across shards (summing int8 quantized with
    # per-shard scales is nonsense); one scalar pmax per leaf is negligible
    gmax = jnp.max(jnp.abs(flat))
    for a in axis_names:
        gmax = jax.lax.pmax(gmax, a)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    new_err = (flat - q.astype(jnp.float32) * scale)[:size].reshape(g.shape)
    # leg 1: reduce-scatter (int32 accumulation of the int8 payload)
    mine = q.reshape(n_dev, -1).astype(jnp.int32)
    for a in axis_names:
        mine = jax.lax.psum_scatter(mine, a, scatter_dimension=0, tiled=True)
    mean = mine.reshape(-1).astype(jnp.float32) * scale / n_dev
    # leg 2: requantize + all-gather (int8), again with an agreed scale
    mmax = jnp.max(jnp.abs(mean))
    for a in axis_names:
        mmax = jax.lax.pmax(mmax, a)
    s2 = jnp.maximum(mmax, 1e-12) / 127.0
    q2 = jnp.clip(jnp.round(mean / s2), -127, 127).astype(jnp.int8)
    gathered = q2
    for a in reversed(axis_names):
        gathered = jax.lax.all_gather(gathered, a, tiled=True)
    out = gathered.astype(jnp.float32)[:flat.shape[0]] * s2
    return out[:size].reshape(g.shape), new_err


def ef_allreduce(grads, err_state, axis_names: tuple[str, ...]):
    """Tree version of the compressed mean; call inside shard_map."""
    pairs = jax.tree.map(lambda g, e: _compress_leaf(g, e, axis_names),
                         grads, err_state)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(
        x[0], "shape")
    out = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
    err = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
    return out, err


def make_compressed_dp_train_step(cfg, mesh: Mesh,
                                  opt_cfg: OptConfig = OptConfig(),
                                  axes: tuple[str, ...] = ("data",),
                                  loss_chunk: int = 512):
    """Replicated-model DP train step with compressed gradient exchange.

    Suitable for models that fit one device (the paper's own training example
    scale); the model axis stays unused.  Batch is sharded over ``axes``.
    """
    axis_names = tuple(a for a in axes if a in mesh.axis_names)
    rep = P()
    dp = P(axis_names)

    def local(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(cfg, p, batch, mesh=None,
                                loss_chunk=loss_chunk))(params)
        grads, err = ef_allreduce(grads, err, axis_names)
        loss = jax.lax.pmean(loss, axis_names)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, err, {"loss": loss, **om}

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def step(params, opt_state, err, batch):
        in_specs = (specs_like(params, rep), specs_like(opt_state, rep),
                    specs_like(err, rep),
                    jax.tree.map(lambda _: dp, batch))
        out_specs = (specs_like(params, rep), specs_like(opt_state, rep),
                     specs_like(err, rep), {"loss": rep, "lr": rep,
                                            "grad_norm": rep})
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        return fn(params, opt_state, err, batch)

    return step
