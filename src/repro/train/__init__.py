from repro.train.optimizer import (OptConfig, adamw_init, adamw_update,
                                   lr_at, opt_abstract, opt_pspecs)
from repro.train.pipeline import ABAPipeline, PipelineEpoch
from repro.train.train_step import make_train_step

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_at",
           "opt_abstract", "opt_pspecs", "make_train_step",
           "ABAPipeline", "PipelineEpoch"]
