"""AdamW + LR schedule, written against plain pytrees (no optax here).

Moments are fp32 regardless of param dtype (bf16 params at deepseek scale);
the update path casts through fp32.  Global-norm clipping included.  The
state tree mirrors the param tree so the same PartitionSpecs apply (ZeRO:
moments inherit the weights' FSDP sharding).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptConfig(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_abstract(abstract_params):
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(z, abstract_params),
        "v": jax.tree.map(z, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_pspecs(param_pspecs):
    from jax.sharding import PartitionSpec as P
    return {
        "m": param_pspecs,
        "v": param_pspecs,
        "step": P(),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, grads, opt_state, params):
    step = opt_state["step"]
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (u + decay)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "m": tdef.unflatten([o[1] for o in out]),
        "v": tdef.unflatten([o[2] for o in out]),
        "step": step + 1,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
