"""train_step / serve_step builders -- the functions the dry-run lowers and
the launcher executes.

``make_train_step`` returns a pure (params, opt_state, batch) -> (params,
opt_state, metrics) function with optional microbatch gradient accumulation
(scan over microbatches: compute/comm overlap comes from the XLA latency
hiding scheduler; accumulation keeps the peak activation footprint at one
microbatch).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.train.optimizer import OptConfig, adamw_update


def make_train_step(cfg, mesh, opt_cfg: OptConfig = OptConfig(),
                    microbatches: int = 1, loss_chunk: int = 512):
    """Build the jittable train step for a model config on a mesh."""

    def loss_fn(params, batch):
        return T.lm_loss(cfg, params, batch, mesh=mesh, loss_chunk=loss_chunk)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, micro):
                loss, g = jax.value_and_grad(loss_fn)(params, micro)
                return (carry[0] + loss,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.float32(0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(acc, zero, mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def make_serve_step(cfg, mesh):
    """One decode step for a running batch: (params, cache, kv_len, tokens)
    -> (next_tokens, logits, cache).  Greedy head (sampling lives in
    repro.serve.generate)."""

    def serve_step(params, cache, kv_len, tokens):
        logits, cache = T.decode_step(cfg, params, cache, kv_len, tokens,
                                      mesh=mesh)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, cache

    return serve_step


def make_prefill_step(cfg, mesh, max_len: int):
    def prefill_step(params, tokens, extra=None, enc_frames=None):
        return T.prefill(cfg, params, tokens, max_len, mesh=mesh,
                         extra_embeds=extra, enc_frames=enc_frames)
    return prefill_step
