"""Async overlapped anticlustered-minibatch pipeline for the training stack.

The paper's headline ML application -- one anticluster per SGD mini-batch --
only pays off at scale if the per-epoch partition hides behind the training
compute.  :class:`ABAPipeline` makes that overlap structural instead of
aspirational:

* it owns one warm :class:`repro.anticluster.AnticlusterEngine` session for
  the whole run (compile once, warm-start every epoch -- exactly the
  :class:`repro.data.minibatch.ABABatchSequencer` contract);
* at the *start* of epoch ``t`` it dispatches epoch ``t+1``'s re-partition
  without blocking (:meth:`AnticlusterEngine.dispatch_repartition`: JAX's
  async dispatch enqueues the compiled solve; the host thread never touches
  ``block_until_ready`` until the epoch boundary), so the solve drains while
  the consumer runs train steps;
* the label/permutation buffers are **double-buffered**: the current epoch's
  batch schedule reads one slot while the in-flight solve's results land in
  the other; slots flip at the epoch boundary;
* minibatches come out of an iterator API -- ``for epoch in
  pipeline.epochs(E, features=...): for idx in epoch: ...`` -- that
  ``repro.launch.train`` and ``benchmarks/perf_iterations.py`` consume in
  place of ad-hoc sequencer calls.

Determinism is bit-for-bit the sequencer's: batch membership comes from the
same engine route (``_auto_or_flat_spec``) and the same schedule builder
(``build_batch_schedule``), the per-epoch batch order from the same
counter-based rng (``epoch_order``) -- ``tests/test_pipeline.py`` pins
pipeline-vs-sequencer equality of labels and batch order per epoch, on one
device and under the 2-device mesh-smoke job.

When overlap is impossible -- a host-callback solver like ``"scipy"``
occupies the host thread while it "runs on device"
(``Solver.host_callback``) -- the pipeline falls back **loudly** (one
``RuntimeWarning``) to synchronous sequencing: same results, no overlap.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.anticluster import AnticlusterEngine
from repro.data.minibatch import (_auto_or_flat_spec, build_batch_schedule,
                                  epoch_order)

__all__ = ["ABAPipeline", "PipelineEpoch"]


class PipelineEpoch:
    """One epoch's minibatch schedule (iterable of batch index arrays).

    Yields ``len(self)`` numpy index arrays into the dataset, in the
    epoch's deterministic order.  ``gathered(data)`` is the convenience
    iterator over ``data[idx]`` slices for array-like datasets.  While this
    epoch is being consumed, the *next* epoch's partition is already in
    flight (unless the pipeline fell back to synchronous mode).
    """

    def __init__(self, index: int, batches, order: np.ndarray):
        self.index = int(index)
        self.order = order
        self._batches = batches

    def __len__(self) -> int:
        return len(self.order)

    def __iter__(self):
        for b in self.order:
            yield self._batches[b]

    def gathered(self, data):
        """Yield ``data[idx]`` per batch (token rows, images, ...)."""
        for idx in self:
            yield data[idx]


class _SyncSolve:
    """Deferred *synchronous* repartition (the loud-fallback twin of
    :class:`repro.anticluster.PendingRepartition`): nothing is dispatched at
    construction; ``wait()`` runs the blocking ``repartition`` at the epoch
    boundary, exactly where the sequencer would."""

    def __init__(self, engine, x, state):
        self._engine, self._x, self._state = engine, x, state

    def wait(self):
        return self._engine.repartition(self._x, self._state)


class ABAPipeline:
    """Warm-session anticlustered minibatches with epoch-overlapped solves.

    Args (mirroring :class:`~repro.data.minibatch.ABABatchSequencer`):
      features: (N, D) embedding anticlustered into K = N // batch_size
        batches.  The constructor's cold partition compiles the engine's
        one executable; every later epoch warm-starts it
        (``engine.compile_count`` stays 1).
      batch_size: examples per step.
      seed: drives the per-epoch batch-order permutation (bit-identical to
        the sequencer's / ``launch.train``'s counter-based rng).
      chunk_size / max_k / mesh / data_axes: forwarded to the engine spec
        exactly as the sequencer forwards them (mesh sessions dispatch the
        same single jitted ``shard_map`` executable asynchronously).
      solver: optional LAP backend override (registry name).  Host-callback
        backends (``"scipy"``) force the loud synchronous fallback.

    The timed path runs the engine with ``stats=False`` -- diversity stats
    and the dual certificate are host/device work outside the solve that
    does not change labels (pinned by ``tests/test_pipeline.py``); call
    :meth:`diversity_stats` when you want the numbers.
    """

    def __init__(self, features: np.ndarray, batch_size: int, *,
                 max_k: int = 512, seed: int = 0, chunk_size="auto",
                 mesh=None, data_axes="auto", solver: str | None = None):
        n = features.shape[0]
        self.batch_size = batch_size
        self.k = max(n // batch_size, 1)
        self.n_used = self.k * batch_size
        self.seed = seed
        spec = _auto_or_flat_spec(self.k, max_k, chunk_size, mesh=mesh,
                                  data_axes=data_axes).evolve(stats=False)
        if solver is not None:
            spec = spec.evolve(solver=solver)
        self.engine = AnticlusterEngine(spec)
        x0 = jnp.asarray(features[:self.n_used])
        self.result, self._state = self.engine.partition(x0)
        self._dtype = spec.dtype
        # double buffer: two (labels, batches) slots; the active one feeds
        # the current epoch's schedule, the other receives the in-flight
        # solve's results at the boundary, then they flip.
        self._slots: list[Any] = [None, None]
        self._active = 0
        self._fill_slot(self._active, np.asarray(self.result.labels))
        self.overlapped = bool(self.engine.overlap_capable(x0))
        self._warned_sync = False

    # -- buffers -----------------------------------------------------------

    def _fill_slot(self, slot: int, labels: np.ndarray) -> None:
        self._slots[slot] = (labels, build_batch_schedule(labels, self.k))

    def _flip_to(self, labels: np.ndarray) -> None:
        back = 1 - self._active
        self._fill_slot(back, labels)
        self._active = back

    @property
    def labels(self) -> np.ndarray:
        """Current epoch's anticluster labels (the active buffer)."""
        return self._slots[self._active][0]

    @property
    def batches(self):
        """Current epoch's batch membership (the active buffer)."""
        return self._slots[self._active][1]

    def __len__(self) -> int:
        return self.k

    # -- stats -------------------------------------------------------------

    def diversity_stats(self, features: np.ndarray):
        """(sd, range) of per-batch diversity under the current labels."""
        from repro.core.objective import diversity_per_cluster
        f = jnp.asarray(features[:self.n_used])
        div = np.asarray(diversity_per_cluster(
            f, jnp.asarray(self.labels), self.k))
        return float(div.std()), float(div.max() - div.min())

    # -- the iterator API --------------------------------------------------

    def epochs(self, n_epochs: int, *,
               features: Callable[[int], np.ndarray] | None = None,
               start_epoch: int = 0):
        """Yield :class:`PipelineEpoch` schedules for ``n_epochs`` epochs.

        ``features``: optional per-epoch embedding provider; ``features(e)``
        is warm-repartitioned to produce epoch ``e``'s batch membership for
        ``e > start_epoch`` (epoch ``start_epoch`` uses the constructor's
        partition, like the sequencer's ``epoch(0)``).  The solve for epoch
        ``e+1`` is dispatched *before* epoch ``e`` is handed out, so it
        drains while the consumer trains; the epoch boundary performs the
        one sync.  ``None`` keeps batch membership static (no further
        solves) -- only the batch *order* rotates, which preserves
        ``launch.train``'s restore-replay contract (the schedule is a pure
        function of the step counter).

        With a host-callback solver the overlap is impossible; one
        ``RuntimeWarning`` fires and each solve runs synchronously at its
        epoch boundary instead (same bits, no overlap).
        """
        end = start_epoch + n_epochs
        pending = [None]
        try:
            yield from self._epochs(start_epoch, end, features, pending)
        finally:
            if pending[0] is not None:
                # consumer abandoned the generator mid-flight: finish the
                # dispatched solve so self._state never points at buffers
                # the in-flight call consumed (they were donated)
                with obs.span("pipeline/wait", abandoned=True):
                    self.result, self._state = pending[0].wait()
                self._flip_to(np.asarray(self.result.labels))
                pending[0] = None

    def _epochs(self, start_epoch, end, features, pending):
        for e in range(start_epoch, end):
            if pending[0] is not None:
                # the epoch-boundary sync: how long the consumer actually
                # stalled on the overlapped solve (0 when it fully drained
                # during training) -- the signal the obs trace exists for
                with obs.span("pipeline/wait", epoch=e,
                              overlapped=self.overlapped):
                    self.result, self._state = pending[0].wait()
                self._flip_to(np.asarray(self.result.labels))
            pending[0] = None
            if features is not None and e + 1 < end:
                x_next = jnp.asarray(
                    np.asarray(features(e + 1))[:self.n_used], self._dtype)
                obs.event("pipeline/dispatch", epoch=e + 1,
                          overlapped=self.overlapped)
                if self.overlapped:
                    pending[0] = self.engine.dispatch_repartition(
                        x_next, self._state)
                else:
                    if not self._warned_sync:
                        warnings.warn(
                            f"solver {self.engine.spec.solver!r} executes "
                            "via a host callback: epoch partitions cannot "
                            "overlap with training; falling back to "
                            "synchronous sequencing (same results, no "
                            "overlap)", RuntimeWarning, stacklevel=2)
                        self._warned_sync = True
                    pending[0] = _SyncSolve(self.engine, x_next, self._state)
            # the span brackets the consumer's whole epoch (the generator
            # resumes here when the next epoch is requested), so its dur is
            # train time the dispatched solve had available to overlap with
            with obs.span("pipeline/epoch", epoch=e):
                yield PipelineEpoch(e, self.batches,
                                    epoch_order(self.seed, e, self.k))
