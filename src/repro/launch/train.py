"""Production training launcher with ABA data batching + fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 200 --batch 8 --seq 128 --aba-batching --ckpt-dir /tmp/ckpt

Fault tolerance model (designed for 1000+ nodes; exercised at container
scale):
  * checkpoint every --ckpt-every steps, atomic rename, retention=3;
  * SIGTERM/SIGINT (preemption) -> synchronous checkpoint, clean exit;
  * on start, auto-restore the newest checkpoint (params+opt+step), with
    device_put resharding so the dp width may differ from the writer's
    (elastic restart);
  * the ABA batch schedule is DETERMINISTIC given (dataset, batch size,
    seed): after restore, the step counter alone reproduces the exact
    mini-batch sequence -- no data-loader state to persist.  Batches come
    from ``repro.train.pipeline.ABAPipeline``'s epoch iterator; with
    ``--refresh-features`` each next epoch's warm re-partition is
    dispatched asynchronously and drains under the current epoch's train
    steps (at the cost of the pure step-counter replay: membership then
    rides the carried engine state);
  * straggler mitigation: per-step wall times are tracked and steps slower
    than --straggler-factor x the running median are logged with the step id
    (on a real pod this feeds the controller that re-slices the batch or
    evicts the slow host; here it is the observability hook).
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.minibatch import epoch_order, random_sequencer_batches
from repro.data.synthetic import lm_token_stream
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.pipeline import ABAPipeline
from repro.train.train_step import make_train_step
from repro.train.compression import (init_error_state,
                                     make_compressed_dp_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-docs", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--aba-batching", action="store_true",
                    help="diverse mini-batches via ABA (the paper's use)")
    ap.add_argument("--refresh-features", action="store_true",
                    help="with --aba-batching: warm re-partition every "
                    "epoch, dispatched asynchronously so the solve overlaps "
                    "the previous epoch's train steps (repro.train.pipeline)."
                    " Batch membership then depends on the carried engine "
                    "state, so restore-replay reproduces the schedule only "
                    "from the same start epoch (default: static membership, "
                    "pure step-counter replay)")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--stop-after", type=int, default=0,
                    help="simulate preemption: checkpoint + exit after N steps")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_host_mesh(args.dp, args.tp)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                        decay_steps=args.steps)

    # ---- data: synthetic LM corpus + ABA diverse batching ------------------
    tokens, feats = lm_token_stream(args.n_docs, args.seq, cfg.vocab_size,
                                    seed=args.seed)
    pipe = None
    if args.aba_batching:
        pipe = ABAPipeline(feats, args.batch, seed=args.seed)
        sd, rg = pipe.diversity_stats(feats)
        print(f"[data] ABA batches: K={len(pipe)} diversity sd={sd:.4f} "
              f"range={rg:.4f}"
              + (" (refresh: overlapped)" if args.refresh_features else ""))
        steps_per_epoch = len(pipe)
    else:
        batches = random_sequencer_batches(args.n_docs, args.batch,
                                           seed=args.seed)
        steps_per_epoch = len(batches)

    # ---- model/optimizer ----------------------------------------------------
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    opt_state = adamw_init(params)
    if args.grad_compression:
        err = init_error_state(params)
        step_fn = jax.jit(make_compressed_dp_train_step(cfg, mesh, opt_cfg))
    else:
        err = None
        step_fn = jax.jit(make_train_step(cfg, mesh, opt_cfg,
                                          loss_chunk=min(128, args.seq)))

    start_step = 0
    if args.ckpt_dir:
        state = {"params": params, "opt": opt_state}
        restored, rstep = ckpt.restore(args.ckpt_dir, state)
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = rstep
            print(f"[restore] resumed from step {rstep}")

    stop = {"flag": False}

    def _preempt(signum, frame):
        print(f"[signal] {signum}: checkpoint + exit")
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _preempt)
    signal.signal(signal.SIGINT, _preempt)

    def save(step):
        if args.ckpt_dir:
            path = ckpt.save(args.ckpt_dir, step,
                             {"params": params, "opt": opt_state})
            print(f"[ckpt] step {step} -> {path}")

    def epoch_batches():
        """(step, idx) pairs from ``start_step`` on, epoch-major.

        The ABA path consumes ``ABAPipeline.epochs`` -- with
        ``--refresh-features`` every next epoch's partition is dispatched
        before the current epoch's steps run, so the solve drains under the
        training compute.  Without refresh (and on the random path) the
        schedule stays the deterministic restore-replay one: membership
        fixed, per-epoch order a pure function of ``(seed, epoch)``.
        """
        start_epoch = start_step // steps_per_epoch
        n_epochs = -(-args.steps // steps_per_epoch) - start_epoch
        if pipe is not None:
            refresh = (lambda e: feats) if args.refresh_features else None
            epochs_it = pipe.epochs(n_epochs, features=refresh,
                                    start_epoch=start_epoch)
        else:
            epochs_it = ((batches[b] for b in
                          epoch_order(args.seed, e, steps_per_epoch))
                         for e in range(start_epoch,
                                        start_epoch + n_epochs))
        step = start_epoch * steps_per_epoch
        for ep in epochs_it:
            for idx in ep:
                if step >= args.steps:
                    return
                if step >= start_step:
                    yield step, idx
                step += 1

    times = []
    losses = []
    for step, idx in epoch_batches():
        batch = {"tokens": jnp.asarray(tokens[idx])}
        t0 = time.time()
        if err is not None:
            params, opt_state, err, metrics = step_fn(params, opt_state, err,
                                                      batch)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        times.append(dt)
        losses.append(loss)
        med = float(np.median(times[-50:]))
        if dt > args.straggler_factor * med and len(times) > 10:
            print(f"[straggler] step {step} took {dt:.2f}s "
                  f"(median {med:.2f}s)")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[step {step}] loss={loss:.4f} lr={float(metrics['lr']):.2e}"
                  f" gnorm={float(metrics['grad_norm']):.2f} {dt:.2f}s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(step + 1)
        if stop["flag"] or (args.stop_after and step + 1 >= args.stop_after):
            save(step + 1)
            print(f"[preempt] stopped after step {step}")
            return losses[-1]
    save(args.steps)
    print(f"[done] last-step loss {losses[-1]:.4f} "
          f"(mean last-10 {np.mean(losses[-10:]):.4f})")
    return losses[-1]  # last-step loss: bit-identical under restore-replay


if __name__ == "__main__":
    main()
