"""Trip-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified on this
jax/XLA build), which under-counts scanned transformer stacks by ~n_blocks
and scanned attention by the chunk counts.  This module re-derives
FLOPs / HBM bytes / collective bytes from ``compiled.as_text()`` with loop
multipliers taken from XLA's ``known_trip_count`` backend config.

Methodology (documented in EXPERIMENTS.md):
- FLOPs: 2*M*N*K for every ``dot`` (including dots inside fusions);
  convolutions as 2 * out_elems * kernel_elems; elementwise ops ignored
  (matmuls dominate every assigned arch).
- HBM bytes: operands + results of *top-level* (post-fusion) ops; fusion
  internals are registers/VMEM.  dynamic-update-slice counts the updated
  slice, not the full buffer.  parameter/tuple/gte/bitcast/reshape are free.
- Collectives: result bytes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, times enclosing trip counts.
- while bodies and conditions multiply by known_trip_count (default 1 +
  ``unknown_trips`` flag when absent); conditionals take the max branch.

Everything is per-device: the module text is the SPMD-partitioned program.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
# tuple types may contain /*index=N*/ comments -- allow anything but parens
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR = re.compile(
    r"(?:body|to_apply|calls)=\{?%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES = re.compile(
    r"(?:true_computation|false_computation|branch_computations=\{[^}]*)"
    r"=?%?([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start")

_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "reshape", "after-all", "partition-id", "replica-id",
             "opt-barrier", "custom-call", "get-dimension-size"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            stripped = line.strip()
            m = _COMP_HDR.match(stripped)
            if m and line.rstrip().endswith("{") and "->" in stripped:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.by_name[op.name] = op
    return comps, entry


def _operand_refs(op: Op, comp: Computation, limit: int) -> list:
    """First ``limit`` operand Ops of ``op``, robust to text-format drift.

    Some XLA builds print operand lists with inline types
    (``dot(f32[128,128]{1,0} %lhs, ...)``), others without the '%' name
    prefix; candidate tokens are filtered through the computation's symbol
    table so type/dim tokens can never shadow an operand name.
    """
    refs = []
    for name in re.findall(r"%?([\w\.\-]+)", op.rest.split(")", 1)[0]):
        ref = comp.by_name.get(name)
        if ref is not None:
            refs.append(ref)
            if len(refs) == limit:
                break
    return refs


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    dims = _shape_dims(op.type_str) or []
    for d in dims:
        out_elems *= d
    # contracted size from lhs operand shape
    cm = _CONTRACT.search(op.rest)
    k = 1
    if cm:
        lhs = _operand_refs(op, comp, 1)
        if lhs:
            ldims = _shape_dims(lhs[0].type_str) or []
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(ldims):
                    k *= ldims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for d in (_shape_dims(op.type_str) or []):
        out_elems *= d
    refs = _operand_refs(op, comp, 2)
    k = 1
    if len(refs) == 2:
        for d in (_shape_dims(refs[1].type_str) or []):
            k *= d
    return 2.0 * out_elems * k


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        if self.entry is None or self.entry not in self.comps:
            cands = [n for n in self.comps if "main" in n]
            self.entry = cands[0] if cands else max(
                self.comps, key=lambda n: len(self.comps[n].ops))
        self._memo: dict[str, dict] = {}
        self.unknown_trips = 0

    def _operand_bytes(self, op: Op, comp: Computation) -> int:
        total = 0
        for name in re.findall(r"%([\w\.\-]+)", op.rest.split(")", 1)[0]):
            ref = comp.by_name.get(name)
            if ref is not None:
                total += _shape_bytes(ref.type_str)
        return total

    def comp_cost(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        out = {"flops": 0.0, "bytes": 0.0, "coll": {}, "transcendentals": 0.0}
        self._memo[name] = out
        if comp is None:
            return out
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                tm = _TRIP_RE.search(op.rest)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    self.unknown_trips += 1
                cm = _CALL_ATTR.search(op.rest)
                cond = _COND_ATTR.search(op.rest)
                for sub, mult in ((cm, trips), (cond, trips + 1)):
                    if sub:
                        c = self.comp_cost(sub.group(1))
                        out["flops"] += mult * c["flops"]
                        out["bytes"] += mult * c["bytes"]
                        for k, v in c["coll"].items():
                            out["coll"][k] = out["coll"].get(k, 0) + mult * v
                continue
            if kind == "conditional":
                subs = _BRANCHES.findall(op.rest)
                if subs:
                    costs = [self.comp_cost(s) for s in subs]
                    best = max(costs, key=lambda c: c["flops"] + c["bytes"])
                    out["flops"] += best["flops"]
                    out["bytes"] += best["bytes"]
                    for k, v in best["coll"].items():
                        out["coll"][k] = out["coll"].get(k, 0) + v
                continue
            if kind in ("call", "async-start"):
                cm = _CALL_ATTR.search(op.rest)
                if cm:
                    c = self.comp_cost(cm.group(1))
                    out["flops"] += c["flops"]
                    out["bytes"] += c["bytes"]
                    for k, v in c["coll"].items():
                        out["coll"][k] = out["coll"].get(k, 0) + v
                continue
            if kind == "fusion":
                cm = _CALL_ATTR.search(op.rest)
                if cm:
                    c = self.comp_cost(cm.group(1))
                    out["flops"] += c["flops"]  # dots inside fusions count
                out["bytes"] += (_shape_bytes(op.type_str)
                                 + self._operand_bytes(op, comp))
                continue
            if kind == "dot":
                out["flops"] += _dot_flops(op, comp)
                out["bytes"] += (_shape_bytes(op.type_str)
                                 + self._operand_bytes(op, comp))
                continue
            if kind == "convolution":
                out["flops"] += _conv_flops(op, comp)
                out["bytes"] += (_shape_bytes(op.type_str)
                                 + self._operand_bytes(op, comp))
                continue
            if kind in COLLECTIVES:
                b = _shape_bytes(op.type_str)
                key = kind.replace("-start", "")
                out["coll"][key] = out["coll"].get(key, 0) + b
                out["bytes"] += b + self._operand_bytes(op, comp)
                continue
            if kind == "dynamic-update-slice":
                names = re.findall(r"%([\w\.\-]+)", op.rest)
                upd = comp.by_name.get(names[1]) if len(names) > 1 else None
                b = _shape_bytes(upd.type_str) if upd else 0
                out["bytes"] += 2 * b
                continue
            if kind in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced/gathered elements, not the operand
                # (a scan step slicing its xs must not be charged the whole
                # sequence -- that error inflated SSM traffic by ~30x)
                out["bytes"] += 2 * _shape_bytes(op.type_str)
                continue
            if kind in _FREE_OPS or kind.endswith("-done"):
                continue
            # generic materializing op (copy, gather, scatter, slice, ...)
            out["bytes"] += (_shape_bytes(op.type_str)
                             + self._operand_bytes(op, comp))
        return out

    def total(self) -> dict:
        c = self.comp_cost(self.entry)
        return {"flops": c["flops"], "bytes": c["bytes"],
                "collectives": dict(c["coll"]),
                "collective_bytes": float(sum(c["coll"].values())),
                "unknown_trip_whiles": self.unknown_trips}


def analyze(text: str) -> dict:
    return HloCost(text).total()
