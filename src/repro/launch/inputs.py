"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

No device allocation happens here -- everything is abstract, exactly what
``jax.jit(...).lower()`` needs.  The modality frontends are stubs per the
assignment: [vlm] gets precomputed patch embeddings, [audio] gets precomputed
frame embeddings.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.models import transformer as T
from repro.sharding.specs import to_pspec


class ShapeCell(NamedTuple):
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train", 4096, 256),
    "prefill_32k": ShapeCell("prefill", 32768, 32),
    "decode_32k": ShapeCell("decode", 32768, 128),
    "long_500k": ShapeCell("decode", 524288, 1),
}

# long_500k needs a sub-quadratic path: run only for SSM/hybrid (DESIGN.md
# notes the skip rationale for the full-attention archs).
LONG_OK_FAMILIES = ("ssm", "hybrid")

VLM_PATCHES = 256  # stub patch-embedding prefix length for [vlm] train/prefill


def cell_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False, ("full-attention arch: no sub-quadratic path at 500k "
                       "(see DESIGN.md)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, cell: ShapeCell) -> dict:
    """Abstract training/serving batch for one cell."""
    b, s = cell.batch, cell.seq
    out = {"tokens": _sds((b, s), jnp.int32)}
    if cell.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32)
    if cfg.mrope_sections:
        out["positions"] = _sds((b, s, 3), jnp.int32)
    if cfg.frontend == "vision" and cell.kind in ("train", "prefill"):
        out["extra_embeds"] = _sds((b, VLM_PATCHES, cfg.d_model),
                                   jnp.dtype(cfg.compute_dtype))
    if cfg.enc_layers and cell.kind in ("train", "prefill"):
        out["enc_frames"] = _sds((b, cfg.enc_ctx, cfg.d_model),
                                 jnp.dtype(cfg.compute_dtype))
    return out


def batch_shardings(cfg, cell: ShapeCell, mesh: Mesh) -> dict:
    an = mesh.axis_names

    def sh(*tags):
        return NamedSharding(mesh, to_pspec(tags, an))

    out = {"tokens": sh("dp", None)}
    if cell.kind == "train":
        out["labels"] = sh("dp", None)
    if cfg.mrope_sections:
        out["positions"] = sh("dp", None, None)
    if cfg.frontend == "vision" and cell.kind in ("train", "prefill"):
        out["extra_embeds"] = sh("dp", None, None)
    if cfg.enc_layers and cell.kind in ("train", "prefill"):
        out["enc_frames"] = sh("dp", None, None)
    return out


def param_shardings(cfg, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        T.param_pspecs(cfg, mesh.axis_names))


def cache_shardings(cfg, cell: ShapeCell, mesh: Mesh):
    enc_len = cfg.enc_ctx if cfg.enc_layers else 0
    specs = T.cache_pspecs(cfg, cell.batch, cell.seq, mesh.axis_names,
                           enc_len=enc_len)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def abstract_cache(cfg, cell: ShapeCell):
    enc_len = cfg.enc_ctx if cfg.enc_layers else 0
    return T.abstract_cache(cfg, cell.batch, cell.seq, enc_len=enc_len)
