import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init.  (Override for quick local tests via DRYRUN_DEVICES.)
if os.environ.get("DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
on placeholder devices, record memory/cost/collective analysis for the
roofline (EXPERIMENTS.md S`Dry-run / S`Roofline).

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

Results are appended incrementally to the JSON cache so a crash loses at most
one cell and re-runs skip completed cells.
"""

import argparse
import gc
import gzip
import json
import os.path
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import hlo_cost
from repro.launch import inputs as I
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.registry import ARCHS, get_config
from repro.sharding.specs import to_pspec
from repro.train.optimizer import OptConfig, opt_abstract
from repro.train.train_step import (make_prefill_step, make_serve_step,
                                    make_train_step)

# --- TPU v5e-class hardware model (per chip) --------------------------------
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (result-shape sizes)."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    return out


def _active_params(cfg, abstract) -> tuple[int, int]:
    """(total, active) param counts; active discounts unrouted experts."""
    flat = jax.tree_util.tree_flatten_with_path(abstract)[0]
    total = sum(l.size for _, l in flat)
    expert = sum(l.size for p, l in flat
                 if "mlp" in str(p) and l.ndim == 4)
    if cfg.moe and expert:
        frac = cfg.moe.top_k / cfg.moe.n_experts
        active = total - expert + int(expert * frac)
    else:
        active = total
    embed = cfg.vocab_size * cfg.d_model
    return total, active - embed  # embedding gather is not matmul FLOPs


def model_flops(cfg, cell, abstract) -> float:
    total, active = _active_params(cfg, abstract)
    if cfg.tie_embeddings:
        active += cfg.vocab_size * cfg.d_model  # unembed matmul reuses table
    tokens = cell.batch * (cell.seq if cell.kind in ("train", "prefill") else 1)
    mult = 6 if cell.kind == "train" else 2
    flops = mult * active * tokens
    # attention score/AV term (only what's actually attended)
    att_layers = sum(1 for s in cfg.pattern if s.mixer in ("attn", "mla"))
    att_layers = att_layers * cfg.n_blocks
    hd = cfg.head_dim if cfg.mla is None else (
        cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim + cfg.mla.v_dim)
    if cell.kind == "train":
        flops += (mult / 2) * 2 * 2 * att_layers * cfg.n_heads * hd \
            * cell.batch * cell.seq ** 2 * 0.5
    elif cell.kind == "prefill":
        flops += 2 * 2 * att_layers * cfg.n_heads * hd * cell.batch \
            * cell.seq ** 2 * 0.5
    else:  # decode: one query against the cache
        flops += 2 * 2 * att_layers * cfg.n_heads * hd * cell.batch * cell.seq
    return flops


def _fix_batch(mesh, sharding_tree, batch):
    """Replicate the batch dim when it doesn't divide the dp shard count."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    if batch % dp == 0:
        return sharding_tree
    dp_vals = {("pod", "data"), ("data",), "data", ("pod",)}

    def fix(ns):
        entries = tuple(None if (e in dp_vals or e == ("pod", "data")) else e
                        for e in ns.spec)
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(fix, sharding_tree,
                        is_leaf=lambda x: isinstance(x, NamedSharding))


# --- ABA data-pipeline cell: the paper's technique on the production mesh ---
ABA_CELLS = {
    # imagenet8-scale mini-batch generation: 1M objects, D=192, K=8192
    # anticlusters (batch size 128).  Auction modeled at 320 Jacobi
    # rounds/phase (fixed_rounds -> known trip counts for the profiler;
    # 320 measured sufficient for valid permutations at 512 columns).
    "aba_1m": dict(n=1 << 20, d=192, k=8192, rounds=320),
}


def lower_aba_cell(shape_name: str, *, multi_pod: bool):
    from repro.core.assignment import AuctionConfig
    from repro.core.sharded import sharded_core
    spec = ABA_CELLS[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    acfg = AuctionConfig(fixed_rounds=spec["rounds"])
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def fn(x):
        return sharded_core(x, spec["k"], mesh, data_axes="auto",
                           auction_config=acfg)

    x_sh = NamedSharding(mesh, P(dp_axes, None))
    out_sh = NamedSharding(mesh, P(dp_axes))
    jitted = jax.jit(fn, in_shardings=(x_sh,), out_shardings=out_sh)
    args = (jax.ShapeDtypeStruct((spec["n"], spec["d"]), jnp.float32),)
    return mesh, jitted, args, spec


def aba_model_flops(spec, mesh) -> float:
    shards = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            shards *= mesh.shape[a]
    k_local = spec["k"] // shards
    return 2.0 * spec["n"] * k_local * spec["d"]


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               overrides: dict | None = None):
    """Build (jitted, abstract_args) for one cell."""
    cfg = get_config(arch, **(overrides or {}))
    cell = I.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    an = mesh.axis_names

    def nsh(*tags):
        return NamedSharding(mesh, to_pspec(tags, an))

    p_sh = I.param_shardings(cfg, mesh)
    p_abs = T.abstract_params(cfg)
    scalar = NamedSharding(mesh, P())

    if cell.kind == "train":
        step = make_train_step(cfg, mesh, OptConfig(), microbatches=1)
        o_sh = {"m": p_sh, "v": p_sh, "step": scalar}
        b_abs = I.batch_specs(cfg, cell)
        b_sh = _fix_batch(mesh, I.batch_shardings(cfg, cell, mesh), cell.batch)
        metric_sh = {"loss": scalar, "lr": scalar, "grad_norm": scalar}
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, metric_sh),
                         donate_argnums=(0, 1))
        args = (p_abs, opt_abstract(p_abs), b_abs)
    elif cell.kind == "decode":
        step = make_serve_step(cfg, mesh)
        c_abs = I.abstract_cache(cfg, cell)
        c_sh = _fix_batch(mesh, I.cache_shardings(cfg, cell, mesh), cell.batch)
        tok = jax.ShapeDtypeStruct((cell.batch, 1), jnp.int32)
        tok_sh = _fix_batch(mesh, {"t": nsh("dp", None)}, cell.batch)["t"]
        logit_sh = _fix_batch(
            mesh, {"l": nsh("dp", None, "tp")}, cell.batch)["l"]
        jitted = jax.jit(step,
                         in_shardings=(p_sh, c_sh, scalar, tok_sh),
                         out_shardings=(tok_sh, logit_sh, c_sh),
                         donate_argnums=(1,))
        args = (p_abs, c_abs, jax.ShapeDtypeStruct((), jnp.int32), tok)
    elif cell.kind == "prefill":
        step = make_prefill_step(cfg, mesh, cell.seq)
        b_abs = I.batch_specs(cfg, cell)
        b_sh = _fix_batch(mesh, I.batch_shardings(cfg, cell, mesh), cell.batch)
        c_sh = _fix_batch(mesh, I.cache_shardings(cfg, cell, mesh), cell.batch)
        logit_sh = _fix_batch(
            mesh, {"l": nsh("dp", None, "tp")}, cell.batch)["l"]
        extra = b_abs.get("extra_embeds")
        frames = b_abs.get("enc_frames")
        jitted = jax.jit(
            lambda p, t, e, f: step(p, t, e, f),
            in_shardings=(p_sh, b_sh["tokens"],
                          b_sh.get("extra_embeds"), b_sh.get("enc_frames")),
            out_shardings=((logit_sh, c_sh)))
        args = (p_abs, b_abs["tokens"], extra, frames)
    else:
        raise ValueError(cell.kind)
    return cfg, cell, mesh, jitted, args


def _save_hlo(arch, shape, mesh_name, text):
    os.makedirs("hlo_cache", exist_ok=True)
    path = f"hlo_cache/{arch}_{shape}_{mesh_name}.hlo.gz"
    with gzip.open(path, "wt") as f:
        f.write(text)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             keep_text: bool = False, save_hlo: bool = False,
             overrides: dict | None = None) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "devices": 512 if multi_pod else 256}
    if overrides:
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    if arch != "aba-pipeline":
        cfg = get_config(arch)
        ok, why = I.cell_applicable(cfg, shape_name)
        if not ok:
            rec.update(status="skipped", reason=why)
            return rec
    try:
        chips = rec["devices"]
        if arch == "aba-pipeline":
            mesh, jitted, args, spec = lower_aba_cell(
                shape_name, multi_pod=multi_pod)
            cfg, cell = None, None
        else:
            cfg, cell, mesh, jitted, args = lower_cell(
                arch, shape_name, multi_pod=multi_pod, overrides=overrides)
        t0 = time.time()
        with mesh:
            lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        text = compiled.as_text()
        # trip-aware re-analysis (XLA's cost_analysis counts loop bodies once)
        hc = hlo_cost.analyze(text)
        coll = hc["collectives"]
        flops = float(hc["flops"])
        bytes_acc = float(hc["bytes"])
        coll_total = float(hc["collective_bytes"])
        if arch == "aba-pipeline":
            mf = aba_model_flops(spec, mesh)
        else:
            mf = model_flops(cfg, cell, args[0])
        terms = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_total / LINK_BW,
        }
        dominant = max(terms, key=terms.get)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            flops_per_device=flops, bytes_per_device=bytes_acc,
            xla_flops_per_device=float(cost.get("flops", 0.0)),
            unknown_trip_whiles=hc["unknown_trip_whiles"],
            collective_bytes_per_device=coll,
            memory=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", None),
                output_bytes=getattr(mem, "output_size_in_bytes", None),
                temp_bytes=getattr(mem, "temp_size_in_bytes", None),
                alias_bytes=getattr(mem, "alias_size_in_bytes", None),
                code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
            ),
            terms=terms, dominant=dominant,
            model_flops_total=mf,
            hlo_flops_total=flops * chips,
            useful_flops_ratio=(mf / (flops * chips)) if flops else None,
        )
        if keep_text:
            rec["hlo_kib"] = len(text) // 1024
        if save_hlo:
            _save_hlo(arch, shape_name, rec["mesh"], text)
        del compiled, lowered, jitted, text
        gc.collect()
    except Exception as e:  # record and continue -- these ARE the bugs
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    return rec


def all_cells(multi_pod_levels=(False, True)):
    for arch in ARCHS:
        for shape in I.SHAPES:
            for mp in multi_pod_levels:
                yield arch, shape, mp
    for shape in ABA_CELLS:
        for mp in multi_pod_levels:
            yield "aba-pipeline", shape, mp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute metrics from hlo_cache without compiling")
    args = ap.parse_args()

    if args.reanalyze:
        results = json.load(open(args.out))
        for rec in results:
            if rec.get("status") != "ok":
                continue
            path = (f"hlo_cache/{rec['arch']}_{rec['shape']}_"
                    f"{rec['mesh']}.hlo.gz")
            if not os.path.exists(path):
                continue
            text = gzip.open(path, "rt").read()
            hc = hlo_cost.analyze(text)
            flops, bytes_acc = float(hc["flops"]), float(hc["bytes"])
            coll_total = float(hc["collective_bytes"])
            rec["flops_per_device"] = flops
            rec["bytes_per_device"] = bytes_acc
            rec["collective_bytes_per_device"] = hc["collectives"]
            rec["terms"] = {
                "compute_s": flops / PEAK_FLOPS,
                "memory_s": bytes_acc / HBM_BW,
                "collective_s": coll_total / LINK_BW,
            }
            rec["dominant"] = max(rec["terms"], key=rec["terms"].get)
            if rec.get("model_flops_total") and flops:
                rec["hlo_flops_total"] = flops * rec["devices"]
                rec["useful_flops_ratio"] = (rec["model_flops_total"]
                                             / (flops * rec["devices"]))
            print(f"[reanalyzed] {rec['arch']} {rec['shape']} {rec['mesh']}"
                  f" dom={rec['dominant']}", flush=True)
        with open(args.out + ".tmp", "w") as f:
            json.dump(results, f, indent=1)
        os.replace(args.out + ".tmp", args.out)
        return

    try:
        done = {(r["arch"], r["shape"], r["mesh"])
                for r in json.load(open(args.out))}
        results = json.load(open(args.out))
    except Exception:
        done, results = set(), []

    if args.all:
        cells = list(all_cells((False, True) if args.both_meshes
                               else (args.multi_pod,)))
    else:
        cells = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        if (arch, shape, mesh_name) in done:
            print(f"[skip-cached] {arch} {shape} {mesh_name}", flush=True)
            continue
        print(f"[run] {arch} {shape} {mesh_name}", flush=True)
        rec = run_cell(arch, shape, multi_pod=mp, save_hlo=args.save_hlo)
        line = {k: rec.get(k) for k in
                ("status", "lower_s", "compile_s", "dominant", "error")}
        print(f"  -> {line}", flush=True)
        results.append(rec)
        with open(args.out + ".tmp", "w") as f:
            json.dump(results, f, indent=1)
        os.replace(args.out + ".tmp", args.out)


if __name__ == "__main__":
    main()
