"""Production mesh builders.

A function, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first init, and smoke tests
must see 1 CPU device while the dry-run forces 512 placeholders).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(dp: int = 1, tp: int = 1):
    """Small mesh over the locally available devices (tests / CPU runs)."""
    n = dp * tp
    devs = jax.devices()[:n]
    assert len(devs) == n, f"need {n} devices, have {len(jax.devices())}"
    return jax.sharding.Mesh(
        __import__("numpy").asarray(devs).reshape(dp, tp), ("data", "model"))
