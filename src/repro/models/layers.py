"""Shared neural building blocks: norms, RoPE/M-RoPE, blockwise (flash)
attention, GQA attention, gated MLP.

Parameters are plain dict pytrees.  Each module exposes ``<name>_defs(cfg)``
returning ``{name: PD(shape, logical_axes, fan_in)}`` and an ``apply``
function; the stack (`transformer.py`) stacks the defs per block pattern and
derives init / abstract shapes / PartitionSpecs from the same metadata.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

_NEG = -1e30


class PD(NamedTuple):
    """Parameter definition: shape + logical sharding tags + init fan-in."""
    shape: tuple
    axes: tuple       # logical tags per dim: 'fsdp' | 'tp' | 'sp' | None
    fan_in: int = 0   # 0 -> zeros/ones init decided by name ('norm'/'bias')


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_apply(cfg, w, x, b=None):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps) * w
        if b is not None:
            out = out + b
    else:  # rmsnorm (gemma-style 1+w so zero-init == identity)
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * (1.0 + w)
    return out.astype(x.dtype)


def norm_defs(cfg, name="norm"):
    d = {name: PD((cfg.d_model,), (None,))}
    if cfg.norm == "layernorm":
        d[name + "_b"] = PD((cfg.d_model,), (None,))
    return d


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(cfg, head_dim: int):
    half = head_dim // 2
    return cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / head_dim)


def apply_rope(cfg, x, positions, head_dim=None):
    """x: (B, S, H, hd); positions: (B, S) or (B, S, 3) for M-RoPE."""
    hd = head_dim or x.shape[-1]
    half = hd // 2
    inv = rope_freqs(cfg, hd)  # (half,)
    if cfg.mrope_sections and positions.ndim == 3:
        # frequency i belongs to section stream_id[i] (temporal / h / w)
        sections = cfg.mrope_sections
        stream = jnp.concatenate([
            jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(stream[None, None, :],
                             positions.shape[:2] + (half,)).astype(jnp.int32),
            axis=2)  # (B, S, half)
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        pos = positions.astype(jnp.float32)[:, :, None]  # (B, S, 1)
    ang = pos * inv[None, None, :]            # (B, S, half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:hd]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if hd < x.shape[-1]:
        rot = jnp.concatenate([rot, x[..., hd:]], axis=-1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise ("flash") attention in pure JAX
# ---------------------------------------------------------------------------

def _softcap(s, cap):
    return cap * jnp.tanh(s / cap) if cap else s


def _flash_shard(qc, kc, vc, mesh):
    """Constrain the chunked attention tensors so the S^2 einsums stay
    TP-sharded (GSPMD loses the fused-weight sharding at the head reshape
    and otherwise replicates attention -- measured 13x flop blowup).

    Preference: shard the KV-head dim when it divides the axis (no k/v
    gather); otherwise shard q rows within each chunk and replicate k/v
    (GQA k/v chunks are small)."""
    if mesh is None or "model" not in mesh.axis_names:
        return qc, kc, vc
    from jax.sharding import NamedSharding, PartitionSpec as P
    tp = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    b = qc.shape[1]
    bs = dp_axes if (b % dp_total == 0) else None

    def c(t, spec):
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    kv, cq = qc.shape[3], qc.shape[2]
    if kv % tp == 0:
        qc = c(qc, P(None, bs, None, "model", None, None))
        kc = c(kc, P(None, bs, None, "model", None))
        vc = c(vc, P(None, bs, None, "model", None))
    elif cq % tp == 0:
        qc = c(qc, P(None, bs, "model", None, None, None))
        kc = c(kc, P(None, bs, None, None, None))
        vc = c(vc, P(None, bs, None, None, None))
    return qc, kc, vc


def _flash_out_anchor(out, mesh, kv, cq):
    """Anchor the per-q-chunk output sharding so GSPMD doesn't bounce the
    inner einsums between q-row sharding and a propagated partial-KV
    sharding (S`Perf B5: the 'involuntary full rematerialization' copies
    were full qc replications -- the dominant collective cost on qwen)."""
    if mesh is None or "model" not in mesh.axis_names:
        return out
    from jax.sharding import NamedSharding, PartitionSpec as P
    tp = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    bs = dp_axes if (out.shape[0] % dp_total == 0) else None
    if kv % tp == 0:
        spec = P(bs, None, "model", None, None)
    elif cq % tp == 0:
        spec = P(bs, "model", None, None, None)
    else:
        return out
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec))


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, chunk_q=512, chunk_kv=1024, q_offset=0,
                    mesh=None):
    """Online-softmax attention with O(chunk^2) live scores.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H % KV == 0 (GQA).
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    Exact (same math as full softmax); used for train/prefill where the full
    score matrix would not fit.  Decode (Sq == 1) uses `attend_one` instead.
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    vd = v.shape[-1]  # may differ from hd (MLA expanded form)
    g = h // kv
    scale = scale or (1.0 / math.sqrt(hd))
    cq, ck = min(chunk_q, sq), min(chunk_kv, skv)
    nq, nk = -(-sq // cq), -(-skv // ck)

    qp = jnp.pad(q, ((0, 0), (0, nq * cq - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * ck - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * ck - skv), (0, 0), (0, 0)))
    # (nq, B, cq, KV, G, hd)
    qc = qp.reshape(b, nq, cq, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = kp.reshape(b, nk, ck, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, ck, kv, vd).transpose(1, 0, 2, 3, 4)
    qc, kc, vc = _flash_shard(qc, kc, vc, mesh)
    kpos = (jnp.arange(nk * ck) + 0).reshape(nk, ck)

    def q_block(qi, qt):
        qpos = q_offset + qi * cq + jnp.arange(cq)

        @jax.checkpoint
        def kv_block(carry, inp):
            m, l, acc = carry
            kt, vt, kpos_t = inp
            s = jnp.einsum("bqkgh,bskh->bkgqs", qt, kt,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= kpos_t[None, :] <= qpos[:, None]
            if window:
                mask &= kpos_t[None, :] > qpos[:, None] - window
            mask &= (kpos_t < skv + 0)[None, :]
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vt.dtype), vt,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, vd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (kc, vc, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = out.transpose(0, 3, 1, 2, 4)  # (B, cq, KV, G, vd)
        return qi + 1, _flash_out_anchor(out, mesh, kv, cq)

    _, outs = jax.lax.scan(q_block, 0, qc)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * cq, h, vd)
    return out[:, :sq].astype(q.dtype)


def attend_one(q, k, v, *, softcap=0.0, scale=None, kv_len=None, window=0):
    """Single-token decode attention; k/v are the full cache (B, S, KV, hd).

    ``kv_len``: number of valid cache entries (scalar or (B,)); the rest is
    masked.  ``window``: sliding-window size (gemma2 local layers) -- only
    the last ``window`` cache entries are attended.  Memory is O(B*H*S)
    scores -- fine sharded; with the cache seq dim sharded over 'model' this
    is GSPMD flash-decode.
    """
    b, sq, h, hd = q.shape
    assert sq == 1
    kv = k.shape[2]
    g = h // kv
    scale = scale or (1.0 / math.sqrt(hd))
    qg = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    if kv_len is not None:
        pos = jnp.arange(k.shape[1])
        lens = (kv_len if jnp.ndim(kv_len) else jnp.full((b,), kv_len))
        valid = pos[None] < lens[:, None]
        if window:
            # q sits at position lens-1: training mask is kpos > qpos - window
            valid = jnp.logical_and(
                valid, pos[None] > lens[:, None] - 1 - window)
        s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def attn_defs(cfg):
    """QKV/O weights in FUSED (H*hd) layout (Megatron convention).

    Head counts like 40/15/28/24 don't divide the 16-way 'model' axis, but
    H*hd does for every assigned arch -- and jit in_shardings requires even
    division.  The per-head view is recovered by reshape inside attn_apply;
    GSPMD propagates internal shardings (uneven is fine internally).
    """
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": PD((d, h * hd), ("fsdp", "tp"), d),
        "wk": PD((d, kv * hd), ("fsdp", "tp"), d),
        "wv": PD((d, kv * hd), ("fsdp", "tp"), d),
        "wo": PD((h * hd, d), ("tp", "fsdp"), h * hd),
    }
    if cfg.qkv_bias:
        defs |= {
            "bq": PD((h * hd,), ("tp",)),
            "bk": PD((kv * hd,), ("tp",)),
            "bv": PD((kv * hd,), ("tp",)),
        }
    return defs


def attn_apply(cfg, p, x, positions, *, spec, cache=None, kv_len=None,
               kv_override=None, mesh=None):
    """x: (B, S, D).  cache: (k, v) each (B, S_cache, KV, hd) for decode.

    kv_override: (k, v) from the encoder for cross-attention.
    Returns (out, new_cache_entry or None).
    """
    b, s, _ = x.shape
    cd = x.dtype
    h_n, kv_n, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
    q = q.reshape(b, s, h_n, hd)
    if kv_override is None:
        k = (x @ p["wk"].astype(cd))
        v = (x @ p["wv"].astype(cd))
        if cfg.qkv_bias:
            k = k + p["bk"].astype(cd)
            v = v + p["bv"].astype(cd)
        k = k.reshape(b, s, kv_n, hd)
        v = v.reshape(b, s, kv_n, hd)
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    else:
        k, v = kv_override
    causal = kv_override is None and not spec_is_encoder(spec)

    if cache is not None and kv_override is None:
        ck, cv = cache
        idx = kv_len if jnp.ndim(kv_len) == 0 else kv_len[0]
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), idx, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), idx, 1)
        out = attend_one(q, ck, cv, softcap=cfg.attn_softcap,
                         kv_len=kv_len + s, window=spec.sliding_window)
        new_cache = (ck, cv)
    else:
        if s == 1 and kv_override is not None:
            out = attend_one(q, k, v, softcap=cfg.attn_softcap)
        else:
            out = flash_attention(
                q, k, v, causal=causal, window=spec.sliding_window,
                softcap=cfg.attn_softcap, chunk_q=cfg.attn_chunk_q,
                chunk_kv=cfg.attn_chunk_kv, mesh=mesh)
        new_cache = None
    y = out.reshape(b, s, h_n * hd) @ p["wo"].astype(cd)
    return y, new_cache


def spec_is_encoder(spec) -> bool:
    return getattr(spec, "encoder", False)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": PD((d, f), ("fsdp", "tp"), d),
        "wg": PD((d, f), ("fsdp", "tp"), d),
        "wo": PD((f, d), ("tp", "fsdp"), f),
    }


def mlp_apply(cfg, p, x):
    cd = x.dtype
    act = jax.nn.silu if cfg.mlp_act == "silu" else (
        lambda t: jax.nn.gelu(t, approximate=True))
    h = act(x @ p["wg"].astype(cd)) * (x @ p["wi"].astype(cd))
    return h @ p["wo"].astype(cd)
