"""The model stack: params metadata -> init/abstract/pspecs, and the three
execution modes (train forward, prefill, decode) over scanned blocks.

Parameters are stacked per block-pattern position (leading n_blocks dim) and
consumed with ``lax.scan`` so HLO size -- and 512-device compile time -- stays
flat in depth.  Every leaf carries logical sharding tags (layers.PD) from
which `param_pspecs` derives PartitionSpecs; there is exactly one source of
truth for shapes/sharding/init.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.models.config import LayerSpec, ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.sharding.specs import to_pspec

# ---------------------------------------------------------------------------
# parameter metadata
# ---------------------------------------------------------------------------

def _add_norm(cfg, d: dict, name: str):
    d[name] = L.PD((cfg.d_model,), (None,))
    if cfg.norm == "layernorm":
        d[name + "_b"] = L.PD((cfg.d_model,), (None,))


def _layer_defs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    d = {}
    _add_norm(cfg, d, "ln1")
    if spec.mixer == "attn":
        d["attn"] = L.attn_defs(cfg)
    elif spec.mixer == "mla":
        d["attn"] = MLA.mla_defs(cfg)
    elif spec.mixer == "mamba":
        d["attn"] = M.mamba_defs(cfg)
    else:
        raise ValueError(spec.mixer)
    if cfg.post_block_norm:
        _add_norm(cfg, d, "ln1_post")
    if spec.cross_attn:
        _add_norm(cfg, d, "ln_x")
        d["xattn"] = L.attn_defs(cfg)
    if spec.mlp != "none":
        _add_norm(cfg, d, "ln2")
        if spec.mlp == "dense":
            d["mlp"] = L.mlp_defs(cfg)
        elif spec.mlp == "moe":
            d["mlp"] = MOE.moe_defs(cfg)
        else:
            raise ValueError(spec.mlp)
        if cfg.post_block_norm:
            _add_norm(cfg, d, "ln2_post")
    return d


def _stack(defs: dict, n: int) -> dict:
    return jax.tree.map(
        lambda pd: L.PD((n,) + pd.shape, (None,) + pd.axes, pd.fan_in),
        defs, is_leaf=lambda x: isinstance(x, L.PD))


def model_defs(cfg: ModelConfig) -> dict:
    d_model, v = cfg.d_model, cfg.padded_vocab
    if cfg.embed_shard == "dmodel":
        # collective-free embedding gather; invalid for tied embeddings
        # (the unembed contraction would need a full-vocab all-reduce)
        assert not cfg.tie_embeddings, "embed_shard=dmodel requires untied"
        embed_pd = L.PD((v, d_model), (None, "tp"), d_model)
    else:
        embed_pd = L.PD((v, d_model), ("tp", None), d_model)
    defs = {
        "embed": embed_pd,
        "final_norm": L.PD((d_model,), (None,)),
        "blocks": _stack(
            {f"L{i}": _layer_defs(cfg, s) for i, s in enumerate(cfg.pattern)},
            cfg.n_blocks),
    }
    if cfg.norm == "layernorm":
        defs["final_norm_b"] = L.PD((d_model,), (None,))
    if not cfg.tie_embeddings:
        defs["unembed"] = L.PD((d_model, v), ("fsdp", "tp"), d_model)
    if cfg.enc_layers:
        enc_spec = LayerSpec(mixer="attn", mlp="dense", encoder=True)
        defs["enc"] = {
            "pos": L.PD((cfg.enc_ctx, d_model), (None, None), d_model),
            "final_norm": L.PD((d_model,), (None,)),
            "blocks": _stack({"L0": _layer_defs(cfg, enc_spec)},
                             cfg.enc_layers),
        }
        if cfg.norm == "layernorm":
            defs["enc"]["final_norm_b"] = L.PD((d_model,), (None,))
    return defs


def _init_leaf(path: str, pd: L.PD, key, dtype):
    name = path.split("/")[-1]
    if "a_log" in name:
        ds = pd.shape[-1]
        base = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, pd.shape).astype(dtype)
    if "d_skip" in name:
        return jnp.ones(pd.shape, dtype)
    if "dt_b" in name:
        return jnp.full(pd.shape, -4.6, dtype)  # softplus^-1(0.01)
    if pd.fan_in == 0 or name.startswith(("ln", "norm")) or name.endswith("_b") \
            or name.startswith(("b", "conv_b", "q_norm", "kv_norm")):
        return jnp.zeros(pd.shape, dtype)
    scale = 1.0 / math.sqrt(max(pd.fan_in, 1))
    return (jax.random.normal(key, pd.shape, jnp.float32) * scale).astype(dtype)


def _flatten_with_path(tree):
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, L.PD))[0]
    return [("/".join(str(getattr(k, "key", k)) for k in path), pd)
            for path, pd in flat]


def init_params(cfg: ModelConfig, key) -> dict:
    defs = model_defs(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    flat = _flatten_with_path(defs)
    keys = jax.random.split(key, len(flat))
    leaves = [_init_leaf(p, pd, k, dtype) for (p, pd), k in zip(flat, keys)]
    treedef = jax.tree_util.tree_structure(
        defs, is_leaf=lambda x: isinstance(x, L.PD))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype),
                        model_defs(cfg),
                        is_leaf=lambda x: isinstance(x, L.PD))


def param_pspecs(cfg: ModelConfig, axis_names) -> dict:
    return jax.tree.map(lambda pd: to_pspec(pd.axes, axis_names),
                        model_defs(cfg),
                        is_leaf=lambda x: isinstance(x, L.PD))


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _constrain(x, mesh, spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, to_pspec(spec, mesh.axis_names)))


def embed_tokens(cfg, params, tokens, mesh=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_cdt(cfg))
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), _cdt(cfg))
    return _constrain(x, mesh, ("dp", None, None))


def _norm(cfg, lp, key, x):
    return L.norm_apply(cfg, lp[key], x, lp.get(key + "_b"))


def _moe_call(cfg, mp, x, mesh):
    if mesh is None:
        return MOE.moe_ref(cfg, mp, x)
    dp_total = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp_total *= mesh.shape[a]
    batch_tag = "dp" if x.shape[0] % dp_total == 0 else None
    x_spec = to_pspec((batch_tag, None, None), mesh.axis_names)
    p_specs = jax.tree.map(
        lambda pd: to_pspec(pd.axes, mesh.axis_names),
        MOE.moe_defs(cfg), is_leaf=lambda v: isinstance(v, L.PD))
    fn = shard_map(
        functools.partial(MOE.moe_apply_local, cfg, axis="model"),
        mesh=mesh, in_specs=(p_specs, x_spec), out_specs=x_spec,
        check_vma=False)
    return fn(mp, x)


def _apply_layer(cfg, spec: LayerSpec, lp, x, positions, *, mesh,
                 mode="train", cache=None, kv_len=None, enc_out=None):
    """One layer; returns (x, new_cache_entry)."""
    new_cache = {}
    h = _norm(cfg, lp, "ln1", x)
    if spec.mixer == "attn":
        if mode == "decode":
            y, kv = L.attn_apply(cfg, lp["attn"], h, positions, spec=spec,
                                 cache=(cache["k"], cache["v"]), kv_len=kv_len)
            new_cache |= {"k": kv[0], "v": kv[1]}
        else:
            y, _ = L.attn_apply(cfg, lp["attn"], h, positions, spec=spec,
                                mesh=mesh)
            if mode == "prefill":
                k, v, mx = _fresh_kv(cfg, lp["attn"], h, positions, kv_len)
                new_cache |= {"k": k, "v": v}
    elif spec.mixer == "mla":
        if mode == "decode":
            y, kv = MLA.mla_apply(cfg, lp["attn"], h, positions,
                                  cache=(cache["ckv"], cache["kr"]),
                                  kv_len=kv_len)
            new_cache |= {"ckv": kv[0], "kr": kv[1]}
        else:
            y, _ = MLA.mla_apply(cfg, lp["attn"], h, positions, mesh=mesh)
            if mode == "prefill":
                ckv, kr = MLA._latents(cfg, lp["attn"], h, positions)
                new_cache |= {"ckv": _pad_cache(ckv, kv_len),
                              "kr": _pad_cache(kr, kv_len)}
    elif spec.mixer == "mamba":
        st = (cache["conv"], cache["h"]) if mode == "decode" else None
        y, st_new = M.mamba_apply(cfg, lp["attn"], h, state=st, mesh=mesh)
        if mode in ("decode", "prefill"):
            new_cache |= {"conv": st_new[0], "h": st_new[1]}
    if cfg.post_block_norm:
        y = _norm(cfg, lp, "ln1_post", y)
    x = x + y

    if spec.cross_attn:
        h = _norm(cfg, lp, "ln_x", x)
        if mode == "decode":
            kv = (cache["xk"], cache["xv"])
            new_cache |= {"xk": cache["xk"], "xv": cache["xv"]}  # read-only
        else:
            kv = _cross_kv(cfg, lp["xattn"], enc_out)
            if mode == "prefill":
                new_cache |= {"xk": kv[0], "xv": kv[1]}
        y, _ = L.attn_apply(cfg, lp["xattn"], h, positions, spec=spec,
                            kv_override=kv, mesh=mesh)
        x = x + y

    if spec.mlp != "none":
        h = _norm(cfg, lp, "ln2", x)
        if spec.mlp == "dense":
            y = L.mlp_apply(cfg, lp["mlp"], h)
        else:
            y = _moe_call(cfg, lp["mlp"], h, mesh)
        if cfg.post_block_norm:
            y = _norm(cfg, lp, "ln2_post", y)
        x = x + y
    return x, new_cache


def _fresh_kv(cfg, p, h, positions, max_len):
    cd = h.dtype
    b, s, _ = h.shape
    kv_n, hd = cfg.n_kv_heads, cfg.head_dim
    k = (h @ p["wk"].astype(cd))
    v = (h @ p["wv"].astype(cd))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    k = k.reshape(b, s, kv_n, hd)
    v = v.reshape(b, s, kv_n, hd)
    k = L.apply_rope(cfg, k, positions)
    return _pad_cache(k, max_len), _pad_cache(v, max_len), max_len


def _pad_cache(arr, max_len):
    """Pad (B, S, ...) to (B, max_len, ...) for the decode cache buffers."""
    s = arr.shape[1]
    pad = [(0, 0)] * arr.ndim
    pad[1] = (0, max_len - s)
    return jnp.pad(arr, pad)


def _cross_kv(cfg, p, enc_out):
    cd = enc_out.dtype
    b, s, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(cd)).reshape(b, s, cfg.n_kv_heads,
                                               cfg.head_dim)
    v = (enc_out @ p["wv"].astype(cd)).reshape(b, s, cfg.n_kv_heads,
                                               cfg.head_dim)
    return k, v


def _run_blocks(cfg, params, x, positions, *, mesh, mode="train",
                cache_blocks=None, kv_len=None, enc_out=None,
                pattern=None, remat=None):
    pattern = pattern or cfg.pattern

    res_spec = ("dp", "sp" if (cfg.seq_parallel and mode == "train")
                else None, None)

    def block_fn(x, bp, bc):
        entries = {}
        for i, spec in enumerate(pattern):
            x, e = _apply_layer(
                cfg, spec, bp[f"L{i}"], x, positions, mesh=mesh, mode=mode,
                cache=None if bc is None else bc[f"L{i}"], kv_len=kv_len,
                enc_out=enc_out)
            entries[f"L{i}"] = e
        return _constrain(x, mesh, res_spec), entries

    if remat if remat is not None else (cfg.remat and mode == "train"):
        block_fn = jax.checkpoint(block_fn)

    if cache_blocks is None:
        def body(c, bp):
            y, e = block_fn(c, bp, None)
            return y, e if mode == "prefill" else None
        x, entries = jax.lax.scan(body, x, params)
    else:
        def body(c, inp):
            bp, bc = inp
            return block_fn(c, bp, bc)
        x, entries = jax.lax.scan(body, x, (params, cache_blocks))
    return x, entries


def _positions_default(cfg, tokens):
    b, s = tokens.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (b, s, 3))
    return pos


def encode(cfg, params, frames, mesh=None):
    """Whisper encoder over precomputed (stub) frame embeddings (B, T, D)."""
    x = frames.astype(_cdt(cfg))
    t = x.shape[1]
    x = x + params["enc"]["pos"][:t][None].astype(x.dtype)
    x = _constrain(x, mesh, ("dp", None, None))
    pos = _positions_default(cfg, x[..., 0])
    enc_pat = (LayerSpec(mixer="attn", mlp="dense", encoder=True),)
    x, _ = _run_blocks(cfg, params["enc"]["blocks"], x, pos, mesh=mesh,
                       pattern=enc_pat)
    return L.norm_apply(cfg, params["enc"]["final_norm"], x,
                        params["enc"].get("final_norm_b"))


def forward_hidden(cfg, params, tokens, *, positions=None, extra_embeds=None,
                   enc_frames=None, mesh=None, remat=None):
    """Token stream -> final hidden states (B, S, D)."""
    x = embed_tokens(cfg, params, tokens, mesh)
    if extra_embeds is not None:  # vlm patch embeddings replace a prefix
        pfx = extra_embeds.astype(x.dtype)
        x = jnp.concatenate([pfx, x[:, pfx.shape[1]:]], axis=1)
    positions = positions if positions is not None else (
        _positions_default(cfg, tokens))
    enc_out = None
    if cfg.enc_layers:
        assert enc_frames is not None
        enc_out = encode(cfg, params, enc_frames, mesh)
    x, _ = _run_blocks(cfg, params["blocks"], x, positions, mesh=mesh,
                       enc_out=enc_out, remat=remat)
    return L.norm_apply(cfg, params["final_norm"], x,
                        params.get("final_norm_b"))


def logits_from_hidden(cfg, params, h):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask[None, None, :], logits, -1e30)
    return logits


def forward(cfg, params, tokens, **kw):
    return logits_from_hidden(
        cfg, params, forward_hidden(cfg, params, tokens, **kw))


def lm_loss(cfg, params, batch, mesh=None, loss_chunk=512):
    """Mean next-token CE; the vocab projection + CE run in seq chunks so
    fp32 logits never materialize at (B, S, V)."""
    tokens = batch["tokens"]
    h = forward_hidden(cfg, params, tokens,
                       positions=batch.get("positions"),
                       extra_embeds=batch.get("extra_embeds"),
                       enc_frames=batch.get("enc_frames"), mesh=mesh)
    targets = batch.get("labels", tokens)
    mask = batch.get("mask")
    b, s, _ = h.shape
    h_in = h[:, :-1]
    t_in = targets[:, 1:]
    m_in = (mask[:, 1:] if mask is not None
            else jnp.ones_like(t_in, jnp.float32))
    c = min(loss_chunk, s - 1)
    n_chunks = (s - 1) // c
    trim = n_chunks * c
    hs = h_in[:, :trim].reshape(b, n_chunks, c, -1).transpose(1, 0, 2, 3)
    ts = t_in[:, :trim].reshape(b, n_chunks, c).transpose(1, 0, 2)
    ms = m_in[:, :trim].reshape(b, n_chunks, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk(carry, inp):
        # checkpointed: backward recomputes the chunk logits instead of
        # keeping (B, chunk, V) fp32 residuals per chunk alive.
        hc, tc, mc = inp
        logits = logits_from_hidden(cfg, params, hc)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ts, ms))
    # remainder tokens (s-1 not divisible by chunk) -- small, direct
    if trim < s - 1:
        logits = logits_from_hidden(cfg, params, h_in[:, trim:])
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, t_in[:, trim:][..., None], axis=-1)[..., 0]
        tot = tot + ((lse - gold) * m_in[:, trim:]).sum()
        cnt = cnt + m_in[:, trim:].sum()
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """Shape/dtype/sharding metadata for the decode cache (one pattern pos)."""
    cd = jnp.dtype(cfg.compute_dtype)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    out = {}
    for i, spec in enumerate(cfg.pattern):
        e = {}
        if spec.mixer == "attn":
            e["k"] = L.PD((batch, max_len, kv, hd), ("dp", "sp", None, None))
            e["v"] = L.PD((batch, max_len, kv, hd), ("dp", "sp", None, None))
        elif spec.mixer == "mla":
            e["ckv"] = L.PD((batch, max_len, cfg.mla.kv_lora),
                            ("dp", "sp", None))
            e["kr"] = L.PD((batch, max_len, cfg.mla.qk_rope_dim),
                           ("dp", "sp", None))
        elif spec.mixer == "mamba":
            e["conv"] = L.PD((batch, cfg.ssm.d_conv - 1, cfg.d_inner),
                             ("dp", None, "tp"))
            e["h"] = L.PD((batch, cfg.d_inner, cfg.ssm.d_state),
                          ("dp", "tp", None))
        if spec.cross_attn:
            e["xk"] = L.PD((batch, enc_len, cfg.n_heads, hd),
                           ("dp", None, "tp", None))
            e["xv"] = L.PD((batch, enc_len, cfg.n_heads, hd),
                           ("dp", None, "tp", None))
        out[f"L{i}"] = e
    stacked = _stack(out, cfg.n_blocks)
    del cd
    return stacked


def abstract_cache(cfg, batch, max_len, enc_len=0):
    cd = jnp.dtype(cfg.compute_dtype)
    defs = cache_defs(cfg, batch, max_len, enc_len)
    flat = _flatten_with_path(defs)
    leaves = [jax.ShapeDtypeStruct(
        pd.shape, jnp.float32 if path.endswith("/h") else cd)
        for path, pd in flat]  # ssm state carries fp32
    treedef = jax.tree_util.tree_structure(
        defs, is_leaf=lambda x: isinstance(x, L.PD))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def cache_pspecs(cfg, batch, max_len, axis_names, enc_len=0):
    return jax.tree.map(lambda pd: to_pspec(pd.axes, axis_names),
                        cache_defs(cfg, batch, max_len, enc_len),
                        is_leaf=lambda x: isinstance(x, L.PD))


def init_cache(cfg, batch, max_len, enc_len=0):
    ab = abstract_cache(cfg, batch, max_len, enc_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), ab)


def decode_step(cfg, params, cache, kv_len, tokens, *, positions=None,
                mesh=None):
    """One token for every sequence.  tokens: (B, 1).  Returns (logits, cache)."""
    x = embed_tokens(cfg, params, tokens, mesh)
    if positions is None:
        b = tokens.shape[0]
        positions = jnp.broadcast_to(
            kv_len.astype(jnp.int32)[None, None], (b, 1))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))
    x, new_cache = _run_blocks(cfg, params["blocks"], x, positions, mesh=mesh,
                               mode="decode", cache_blocks=cache,
                               kv_len=kv_len)
    h = L.norm_apply(cfg, params["final_norm"], x, params.get("final_norm_b"))
    return logits_from_hidden(cfg, params, h), new_cache


def prefill(cfg, params, tokens, max_len, *, positions=None, enc_frames=None,
            extra_embeds=None, mesh=None):
    """Process the prompt, build the cache.  Returns (last-pos logits, cache)."""
    x = embed_tokens(cfg, params, tokens, mesh)
    if extra_embeds is not None:
        pfx = extra_embeds.astype(x.dtype)
        x = jnp.concatenate([pfx, x[:, pfx.shape[1]:]], axis=1)
    positions = positions if positions is not None else (
        _positions_default(cfg, tokens))
    enc_out = None
    if cfg.enc_layers:
        enc_out = encode(cfg, params, enc_frames, mesh)
    x, cache = _run_blocks(cfg, params["blocks"], x, positions, mesh=mesh,
                           mode="prefill", kv_len=max_len, enc_out=enc_out)
    h = L.norm_apply(cfg, params["final_norm"], x[:, -1:],
                     params.get("final_norm_b"))
    return logits_from_hidden(cfg, params, h), cache
