"""Registry mapping --arch ids to ModelConfig builders (one module per arch
lives in repro/configs; this registry is the single lookup point)."""

from __future__ import annotations

import importlib

ARCHS = (
    "qwen2p5_14b",
    "gemma2_2b",
    "gemma_7b",
    "smollm_360m",
    "jamba_v0p1_52b",
    "deepseek_v2_236b",
    "granite_moe_3b",
    "qwen2_vl_7b",
    "falcon_mamba_7b",
    "whisper_medium",
)

ALIASES = {
    "qwen2.5-14b": "qwen2p5_14b",
    "gemma2-2b": "gemma2_2b",
    "gemma-7b": "gemma_7b",
    "smollm-360m": "smollm_360m",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-medium": "whisper_medium",
}


def get_config(arch: str, *, reduced: bool = False, **over):
    name = ALIASES.get(arch, arch).replace("-", "_")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.config()
    if reduced:
        cfg = cfg.reduced()
    if over:
        import dataclasses
        cfg = dataclasses.replace(cfg, **over)
    return cfg
