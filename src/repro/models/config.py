"""Model configuration for the 10 assigned architectures.

A model is a stack of ``n_layers`` layers described by a repeating *block
pattern* (`pattern`), each entry a ``LayerSpec``.  Parameters are stacked per
pattern position with a leading ``n_blocks = n_layers / len(pattern)`` dim and
scanned, which keeps the HLO (and 512-device compile time) small even for
60-layer models.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    n_shared: int = 0            # deepseek shared experts (dense path)
    d_expert: int = 0            # per-expert ffn hidden
    renorm: bool = True          # renormalize top-k probs
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLASpec:
    q_lora: int = 0              # 0 -> full-rank q projection
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    scan_chunk: int = 1          # timesteps unrolled per scan step (S`Perf:
                                 # lets XLA keep the SSM state in registers
                                 # across the chunk; 1 = paper-faithful
                                 # per-step recurrence)


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating block pattern."""
    mixer: str = "attn"          # "attn" | "mla" | "mamba"
    mlp: str = "dense"           # "dense" | "moe" | "none"
    sliding_window: int = 0      # 0 -> global attention
    cross_attn: bool = False     # whisper decoder
    encoder: bool = False        # whisper encoder (non-causal self-attn)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)

    # attention details
    qkv_bias: bool = False
    attn_softcap: float = 0.0    # gemma2: 50.0
    logit_softcap: float = 0.0   # gemma2: 30.0
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (pairs per section)

    # mlp
    mlp_act: str = "silu"        # silu | gelu (GeGLU when gated)

    # norms / embeddings
    norm_eps: float = 1e-6
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    post_block_norm: bool = False  # gemma2 post-norms
    scale_embed: bool = False    # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = False

    moe: MoESpec | None = None
    mla: MLASpec | None = None
    ssm: SSMSpec | None = None

    # encoder-decoder (whisper): encoder layers w/ non-causal self-attn
    enc_layers: int = 0
    enc_ctx: int = 1500          # whisper frame positions after conv stub

    # modality frontends are STUBS: extra embedded inputs concatenated
    # ahead of the token stream ("vlm" patches / "audio" frames)
    frontend: str = "none"       # none | vision | audio

    # training-time details
    remat: bool = True
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # S`Perf knobs (defaults = paper-faithful baseline)
    embed_shard: str = "vocab"   # "vocab" (Megatron) | "dmodel" (untied only:
                                 # gather needs no collective)
    seq_parallel: bool = False   # shard the residual stream's seq dim over
                                 # 'model' between blocks (Megatron-SP):
                                 # divides remat-saved activations by tp

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, len(self.pattern))

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to the 16-way 'model' axis (granite: 49155 ->
        49168; whisper: 51865 -> 51872).  Padded logits are masked to -1e30
        in logits_from_hidden, so loss/argmax are exact."""
        return -(-self.vocab_size // 16) * 16

    @property
    def dt_rank(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    def reduced(self, **over) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=len(self.pattern) * min(2, self.n_blocks),
            d_model=64, n_heads=4, n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16, d_ff=128, vocab_size=256,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_ctx=16 if self.enc_layers else self.enc_ctx,
            attn_chunk_q=16, attn_chunk_kv=16,
            param_dtype="float32", compute_dtype="float32",
            name=self.name + "-smoke",
        )
        if self.moe:
            # capacity_factor >= E/k guarantees zero drops, making smoke
            # outputs exactly mesh-independent (drops depend on local T).
            base["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                d_expert=32, n_shared=min(self.moe.n_shared, 1),
                capacity_factor=8.0)
        if self.mla:
            base["mla"] = MLASpec(q_lora=32 if self.mla.q_lora else 0,
                                  kv_lora=32, qk_nope_dim=16, qk_rope_dim=8,
                                  v_dim=16)
        if self.ssm:
            base["ssm"] = SSMSpec(d_state=4, d_conv=4, expand=2, dt_rank=8)
        if self.mrope_sections:
            half = base["head_dim"] // 2
            t = half // 4
            base["mrope_sections"] = (half - 2 * ((half - t) // 2),
                                      (half - t) // 2, (half - t) // 2)
        base.update(over)
        return dataclasses.replace(self, **base)
