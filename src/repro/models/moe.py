"""Mixture-of-Experts with explicit expert parallelism over the 'model' axis.

Dispatch is capacity-based and sort-free: each (token, choice) pair gets a
rank within its expert via a one-hot cumsum, ranks >= capacity are dropped
(standard dropping MoE), and each model-shard scatters only the slots of its
local experts into an (E_local, C, D) VMEM-friendly buffer.  Expert outputs
are combined with a psum over 'model'.

Rationale (vs GSPMD one-hot dispatch einsums): the dense dispatch tensor is
O(T^2 k D / E) FLOPs -- catastrophic at deepseek scale; the shard_map path
keeps expert compute at T*k*D*F and communication at one (T, D) all-reduce.
(A ragged all-to-all variant is the documented next hillclimb step in
EXPERIMENTS.md SPerf.)

Experts whose count does not divide the 16-way axis are padded (granite:
40 -> 48) with -inf router logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import PD


# Experts are padded to a multiple of the PRODUCTION model-axis width so the
# parameter shapes (and routing math) are identical on every mesh; smaller
# meshes just hold more experts per shard.
EP_GRANULARITY = 16


def padded_experts(cfg) -> int:
    e = cfg.moe.n_experts
    return -(-e // EP_GRANULARITY) * EP_GRANULARITY


def moe_defs(cfg):
    d = cfg.d_model
    m = cfg.moe
    e_pad = padded_experts(cfg)
    f = m.d_expert or cfg.d_ff
    defs = {
        "router": PD((d, e_pad), (None, None), d),
        "wi": PD((e_pad, d, f), ("tp", None, None), d),
        "wg": PD((e_pad, d, f), ("tp", None, None), d),
        "wo": PD((e_pad, f, d), ("tp", None, None), f),
    }
    if m.n_shared:
        # TP-only (no FSDP): must be usable as full-D local blocks inside
        # shard_map without a manual all-gather; they are tiny.
        fs = f * m.n_shared
        defs |= {
            "shared_wi": PD((d, fs), (None, "tp"), d),
            "shared_wg": PD((d, fs), (None, "tp"), d),
            "shared_wo": PD((fs, d), ("tp", None), fs),
        }
    return defs


def _capacity(cfg, n_tokens: int, e_pad: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / e_pad) + 1
    return -(-c // 8) * 8


def moe_apply_local(cfg, p, x, *, axis: str | None):
    """Per-shard MoE; call inside shard_map (axis='model') or alone (axis=None).

    x: (B, S, D) local tokens, replicated over 'model'.
    p['wi'/'wg'/'wo']: local expert slices (E_local, D, F) etc.
    All sizes derive from the param shapes, so routing is identical on every
    mesh (shapes are padded to EP_GRANULARITY at definition time).
    """
    b, s, d = x.shape
    cd = x.dtype
    m = cfg.moe
    t = b * s
    e_pad = p["router"].shape[1]
    e_loc = p["wi"].shape[0]
    xf = x.reshape(t, d)

    # --- routing (replicated across the model axis) -------------------------
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if e_pad > m.n_experts:
        pad_mask = jnp.arange(e_pad) >= m.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)          # (T, k)
    if m.renorm:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # --- sort-free rank within expert ---------------------------------------
    n = t * m.top_k
    flat_e = top_e.reshape(n)
    oh = (flat_e[:, None] == jnp.arange(e_pad)[None, :]).astype(jnp.int32)
    ranks = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(n), flat_e]
    cap = _capacity(cfg, t, e_pad)
    keep = ranks < cap
    slot = flat_e * cap + ranks                            # global slot id

    # --- local dispatch buffer ----------------------------------------------
    shard = jax.lax.axis_index(axis) if axis else 0
    lo = shard * e_loc * cap
    local = jnp.logical_and(keep,
                            jnp.logical_and(slot >= lo, slot < lo + e_loc * cap))
    lslot = jnp.where(local, slot - lo, e_loc * cap)       # sentinel = OOB
    tok = jnp.arange(n, dtype=jnp.int32) // m.top_k
    buf_tok = jnp.full((e_loc * cap,), t, jnp.int32).at[lslot].set(
        tok, mode="drop")
    x_ext = jnp.concatenate([xf, jnp.zeros((1, d), cd)])
    h = x_ext[buf_tok].reshape(e_loc, cap, d)

    # --- expert FFN (grouped matmul over local experts) ----------------------
    act = jax.nn.silu if cfg.mlp_act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    g = act(jnp.einsum("ecd,edf->ecf", h, p["wg"].astype(cd)))
    u = jnp.einsum("ecd,edf->ecf", h, p["wi"].astype(cd))
    y = jnp.einsum("ecf,efd->ecd", g * u, p["wo"].astype(cd))
    y_flat = jnp.concatenate([y.reshape(e_loc * cap, d),
                              jnp.zeros((1, d), cd)])

    # --- combine -------------------------------------------------------------
    picked = y_flat[jnp.minimum(lslot, e_loc * cap)]
    picked = jnp.where(local[:, None], picked, 0.0)
    out = (picked.reshape(t, m.top_k, d)
           * top_p.astype(cd).reshape(t, m.top_k, 1)).sum(axis=1)

    # --- shared experts (dense, TP-sharded like a normal MLP) ---------------
    if m.n_shared:
        gs = act(xf @ p["shared_wg"].astype(cd))
        us = xf @ p["shared_wi"].astype(cd)
        out = out + (gs * us) @ p["shared_wo"].astype(cd)

    if axis:
        out = jax.lax.psum(out, axis)
    return out.reshape(b, s, d)


def moe_ref(cfg, p, x):
    """Single-device oracle: identical math (incl. capacity drops), no mesh."""
    return moe_apply_local(cfg, p, x, axis=None)
