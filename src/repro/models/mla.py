"""Multi-head Latent Attention (deepseek-v2).

Train/prefill use the expanded form (materialize per-head K/V from the
compressed latent); decode uses the **absorbed** form against a compressed
cache of (c_kv, k_rope) -- (kv_lora + rope_dim) floats per token instead of
2*H*head_dim, the memory trick that makes deepseek-v2 decode fit.  The cache
seq dim is sharded over 'model' ('sp'), giving flash-decode partial softmax.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import PD, apply_rope, flash_attention

_NEG = -1e30


def mla_defs(cfg):
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    qk = m.qk_nope_dim + m.qk_rope_dim
    defs = {}
    if m.q_lora:
        defs["wq_down"] = PD((d, m.q_lora), ("fsdp", None), d)
        defs["q_norm"] = PD((m.q_lora,), (None,))
        defs["wq_up"] = PD((m.q_lora, h, qk), (None, "tp", None), m.q_lora)
    else:
        defs["wq"] = PD((d, h, qk), ("fsdp", "tp", None), d)
    defs |= {
        "wkv_down": PD((d, m.kv_lora + m.qk_rope_dim), ("fsdp", None), d),
        "kv_norm": PD((m.kv_lora,), (None,)),
        "wkv_up": PD((m.kv_lora, h, m.qk_nope_dim + m.v_dim),
                    (None, "tp", None), m.kv_lora),
        "wo": PD((h, m.v_dim, d), ("tp", None, "fsdp"), h * m.v_dim),
    }
    return defs


def _queries(cfg, p, x, positions):
    m = cfg.mla
    cd = x.dtype
    if m.q_lora:
        ql = x @ p["wq_down"].astype(cd)
        qlf = ql.astype(jnp.float32)
        ql = (qlf * jax.lax.rsqrt(
            jnp.mean(qlf * qlf, -1, keepdims=True) + cfg.norm_eps)
              * (1.0 + p["q_norm"])).astype(cd)
        q = jnp.einsum("bsl,lhk->bshk", ql, p["wq_up"].astype(cd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(cfg, q_rope, positions)
    return q_nope, q_rope


def _latents(cfg, p, x, positions):
    m = cfg.mla
    cd = x.dtype
    kv = x @ p["wkv_down"].astype(cd)
    c_kv, k_rope = kv[..., :m.kv_lora], kv[..., m.kv_lora:]
    cf = c_kv.astype(jnp.float32)
    c_kv = (cf * jax.lax.rsqrt(jnp.mean(cf * cf, -1, keepdims=True)
                               + cfg.norm_eps) * (1.0 + p["kv_norm"])).astype(cd)
    k_rope = apply_rope(cfg, k_rope[:, :, None, :], positions)[:, :, 0, :]
    return c_kv, k_rope


def mla_apply(cfg, p, x, positions, *, cache=None, kv_len=None, mesh=None):
    """Returns (out, new_cache or None).  cache = (c_kv, k_rope) buffers."""
    m = cfg.mla
    cd = x.dtype
    h = cfg.n_heads
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = _queries(cfg, p, x, positions)

    if cache is None:
        # expanded form (train / prefill without cache)
        c_kv, k_rope = _latents(cfg, p, x, positions)
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv,
                            p["wkv_up"][..., :m.qk_nope_dim].astype(cd))
        v = jnp.einsum("bsl,lhv->bshv", c_kv,
                       p["wkv_up"][..., m.qk_nope_dim:].astype(cd))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      k_nope.shape[:3] + (m.qk_rope_dim,))],
            axis=-1)
        out = flash_attention(q, k, v, causal=True, scale=scale,
                              chunk_q=cfg.attn_chunk_q,
                              chunk_kv=cfg.attn_chunk_kv, mesh=mesh)
        y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(cd))
        return y, None

    # absorbed decode: score/combine directly in latent space
    ckv_buf, krope_buf = cache
    c_new, r_new = _latents(cfg, p, x, positions)
    idx = kv_len if jnp.ndim(kv_len) == 0 else kv_len[0]
    ckv_buf = jax.lax.dynamic_update_slice_in_dim(
        ckv_buf, c_new.astype(ckv_buf.dtype), idx, 1)
    krope_buf = jax.lax.dynamic_update_slice_in_dim(
        krope_buf, r_new.astype(krope_buf.dtype), idx, 1)
    q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope,
                       p["wkv_up"][..., :m.qk_nope_dim].astype(cd))
    s = (jnp.einsum("bqhl,bsl->bhqs", q_abs, ckv_buf.astype(cd),
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhr,bsr->bhqs", q_rope, krope_buf.astype(cd),
                      preferred_element_type=jnp.float32)) * scale
    pos = jnp.arange(ckv_buf.shape[1])
    s = jnp.where((pos < kv_len + x.shape[1])[None, None, None, :], s, _NEG)
    probs = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsl->bqhl", probs.astype(cd), ckv_buf.astype(cd))
    out = jnp.einsum("bqhl,lhv->bqhv", o_lat,
                     p["wkv_up"][..., m.qk_nope_dim:].astype(cd))
    y = jnp.einsum("bqhv,hvd->bqd", out, p["wo"].astype(cd))
    return y, (ckv_buf, krope_buf)
