"""Mamba-1 selective-SSM block (falcon-mamba-7b; jamba's SSM layers).

Training/prefill runs the selective scan sequentially over time with
``lax.scan`` (fp32 carry); the per-step state is (B, d_inner, d_state) --
tiny -- and all wide activations are TP-sharded on d_inner, so the scan is
memory-light.  Decode keeps (conv window, ssm state) and is O(1) per token:
this is what makes the long_500k cell feasible for the SSM/hybrid archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import PD


def mamba_defs(cfg):
    d = cfg.d_model
    di = cfg.d_inner
    s = cfg.ssm
    dtr = cfg.dt_rank
    return {
        "in_proj": PD((d, 2 * di), ("fsdp", "tp"), d),
        "conv_w": PD((s.d_conv, di), (None, "tp"), s.d_conv),
        "conv_b": PD((di,), ("tp",)),
        "x_proj": PD((di, dtr + 2 * s.d_state), ("tp", None), di),
        "dt_w": PD((dtr, di), (None, "tp"), dtr),
        "dt_b": PD((di,), ("tp",)),
        "a_log": PD((di, s.d_state), ("tp", None)),
        "d_skip": PD((di,), ("tp",)),
        "out_proj": PD((di, d), ("tp", "fsdp"), di),
    }


def _split_xproj(cfg, xdbc):
    dtr, ds = cfg.dt_rank, cfg.ssm.d_state
    return (xdbc[..., :dtr], xdbc[..., dtr:dtr + ds], xdbc[..., dtr + ds:])


def _ssm_inputs(cfg, p, xc):
    """Common path after conv: returns (dt, b_in, c_out) with dt softplused."""
    cd = xc.dtype
    xdbc = xc @ p["x_proj"].astype(cd)
    dt_r, b_in, c_out = _split_xproj(cfg, xdbc)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ p["dt_w"].astype(jnp.float32) + p["dt_b"])
    return dt, b_in.astype(jnp.float32), c_out.astype(jnp.float32)


def _anchor(t, mesh, spec_tags):
    """Keep the d_inner sharding alive inside the (transposed) scan --
    without this GSPMD replicates the backward chunk tensors (S`Perf A4)."""
    if mesh is None or "model" not in mesh.axis_names:
        return t
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding.specs import to_pspec
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, to_pspec(spec_tags, mesh.axis_names)))


def mamba_apply(cfg, p, x, *, state=None, mesh=None):
    """x: (B, S, D).  state=None -> full sequence (train/prefill); returns
    (out, final_state).  state=(conv_buf (B, d_conv-1, di), h (B, di, ds))
    -> single-step decode (S == 1), returns (out, new_state).
    """
    s = cfg.ssm
    di = cfg.d_inner
    cd = x.dtype
    b, seq, _d = x.shape
    xz = x @ p["in_proj"].astype(cd)
    x_in, z = xz[..., :di], xz[..., di:]
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, ds)

    if state is None:
        # causal depthwise conv over the full sequence
        xpad = jnp.pad(x_in, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        xc = sum(xpad[:, i:i + seq, :] * p["conv_w"][i].astype(cd)
                 for i in range(s.d_conv)) + p["conv_b"].astype(cd)
        xc = jax.nn.silu(xc)
        dt, b_in, c_out = _ssm_inputs(cfg, p, xc)

        chunk = max(int(getattr(s, "scan_chunk", 1)), 1)
        chunk = chunk if seq % chunk == 0 else 1

        def step(h, inp):
            dt_t, b_t, c_t, x_t = inp  # each (C, B, ...)
            ys = []
            for t in range(chunk):  # unrolled: h stays in registers, XLA
                da = jnp.exp(dt_t[t][:, :, None] * a_mat[None])  # fuses chunk
                h = h * da + (dt_t[t] * x_t[t])[:, :, None] * b_t[t][:, None, :]
                ys.append(jnp.sum(h * c_t[t][:, None, :], axis=-1))
            h = _anchor(h, mesh, ("dp", "tp", None))
            ys = _anchor(jnp.stack(ys), mesh, (None, "dp", "tp"))
            return h, ys

        if chunk > 1:
            step = jax.checkpoint(step)

        def to_xs(a):  # (B, S, F) -> (S/C, C, B, F)
            a = a.transpose(1, 0, 2)
            return a.reshape(seq // chunk, chunk, *a.shape[1:])

        h0 = _anchor(jnp.zeros((b, di, s.d_state), jnp.float32), mesh,
                     ("dp", "tp", None))
        xs = (
            _anchor(to_xs(dt), mesh, (None, None, "dp", "tp")),
            _anchor(to_xs(b_in), mesh, (None, None, "dp", None)),   # (.., ds)
            _anchor(to_xs(c_out), mesh, (None, None, "dp", None)),  # (.., ds)
            _anchor(to_xs(x_in.astype(jnp.float32)), mesh,
                    (None, None, "dp", "tp")),
        )
        h_fin, ys = jax.lax.scan(step, h0, xs)
        y = (ys.reshape(seq, b, di).transpose(1, 0, 2)
             + x_in.astype(jnp.float32) * p["d_skip"])
        out = (y.astype(cd) * jax.nn.silu(z)) @ p["out_proj"].astype(cd)
        conv_buf = xpad[:, -(s.d_conv - 1):, :] if s.d_conv > 1 else (
            jnp.zeros((b, 0, di), cd))
        return out, (conv_buf.astype(cd), h_fin)

    # ---- single-step decode -------------------------------------------------
    conv_buf, h = state
    assert seq == 1
    window = jnp.concatenate([conv_buf, x_in.astype(conv_buf.dtype)], axis=1)
    xc = (jnp.einsum("btd,td->bd", window.astype(cd),
                     p["conv_w"].astype(cd)) + p["conv_b"].astype(cd))
    xc = jax.nn.silu(xc)[:, None, :]
    dt, b_in, c_out = _ssm_inputs(cfg, p, xc)
    dt_t, b_t, c_t = dt[:, 0], b_in[:, 0], c_out[:, 0]
    x_t = x_in[:, 0].astype(jnp.float32)
    da = jnp.exp(dt_t[:, :, None] * a_mat[None])
    h = h * da + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
    y = jnp.sum(h * c_t[:, None, :], axis=-1) + x_t * p["d_skip"]
    out = (y[:, None, :].astype(cd) * jax.nn.silu(z)) @ p["out_proj"].astype(cd)
    return out, (window[:, 1:, :], h)
