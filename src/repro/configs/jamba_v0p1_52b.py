"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 -- Mamba+attn 1:7 interleave, MoE every other
layer.  [arXiv:2403.19887]"""
from repro.models.config import LayerSpec, ModelConfig, MoESpec, SSMSpec


def config() -> ModelConfig:
    # 8-layer period: attn at index 4; MoE on odd indices (1:1 with dense).
    pat = tuple(
        LayerSpec(mixer="attn" if i == 4 else "mamba",
                  mlp="moe" if i % 2 == 1 else "dense")
        for i in range(8))
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=65536, head_dim=128,
        pattern=pat, norm="rmsnorm", mlp_act="silu",
        moe=MoESpec(n_experts=16, top_k=2, d_expert=14336),
        ssm=SSMSpec(d_state=16, d_conv=4, expand=2, scan_chunk=16),
    )
