"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 -- M-RoPE, dynamic resolution.  Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings.  [arXiv:2409.12191]"""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),  # head_dim/2 = 64 freq pairs
        frontend="vision", mlp_act="silu",
        pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    )
