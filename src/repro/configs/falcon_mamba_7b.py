"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 -- mamba1 architecture.  [arXiv:2410.05355]"""
from repro.models.config import LayerSpec, ModelConfig, SSMSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=65024, head_dim=64,
        pattern=(LayerSpec(mixer="mamba", mlp="none"),),
        ssm=SSMSpec(d_state=16, d_conv=4, expand=2, scan_chunk=16),
    )
