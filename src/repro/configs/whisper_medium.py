"""whisper-medium [audio]: enc-dec 24L d_model=1024 16H d_ff=4096
vocab=51865 -- conv frontend STUB: input_specs() provides precomputed frame
embeddings (B, enc_ctx, D).  [arXiv:2212.04356]"""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51865, head_dim=64,
        norm="layernorm", mlp_act="gelu", frontend="audio",
        enc_layers=24, enc_ctx=1500,
        pattern=(LayerSpec(mixer="attn", mlp="dense", cross_attn=True),),
    )
