"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA kv_lora=512, 2 shared + 160 routed experts top-6.  [arXiv:2405.04434]"""
from repro.models.config import LayerSpec, MLASpec, ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab_size=102400, head_dim=128,
        pattern=(LayerSpec(mixer="mla", mlp="moe"),),
        mla=MLASpec(q_lora=1536, kv_lora=512, qk_nope_dim=128,
                    qk_rope_dim=64, v_dim=128),
        moe=MoESpec(n_experts=160, top_k=6, n_shared=2, d_expert=1536,
                    renorm=False),
        rope_theta=10000.0, mlp_act="silu",
    )
