"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512,
MoE 40e top-8, vocab=49155.  [hf:ibm-granite/granite-3.0-*]"""
from repro.models.config import LayerSpec, ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        pattern=(LayerSpec(mixer="attn", mlp="moe"),),
        moe=MoESpec(n_experts=40, top_k=8, d_expert=512),
        tie_embeddings=True, mlp_act="silu",
    )
