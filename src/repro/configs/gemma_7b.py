"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000 -- GeGLU, head_dim=256.  [arXiv:2403.08295]"""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
        d_ff=24576, vocab_size=256000, head_dim=256,
        mlp_act="gelu", scale_embed=True, tie_embeddings=True,
        pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    )
