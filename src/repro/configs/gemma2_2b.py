"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
-- local+global alternating attention, logit softcap.  [arXiv:2408.00118]"""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        d_ff=9216, vocab_size=256000, head_dim=256,
        attn_softcap=50.0, logit_softcap=30.0,
        mlp_act="gelu", scale_embed=True, tie_embeddings=True,
        post_block_norm=True,
        pattern=(LayerSpec(mixer="attn", mlp="dense", sliding_window=4096),
                 LayerSpec(mixer="attn", mlp="dense")),
    )
