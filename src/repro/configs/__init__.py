"""One config module per assigned architecture (exact public specs) plus the
paper's own ABA workload presets (repro.configs.aba_presets)."""
