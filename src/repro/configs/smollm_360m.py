"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 -- llama-arch small.  [hf:HuggingFaceTB/SmolLM-*]"""
from repro.models.config import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab_size=49152, head_dim=64,
        tie_embeddings=True, mlp_act="silu",
        pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    )
