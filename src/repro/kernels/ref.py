"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


@jax.jit
def cdist_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(m, d), (n, d) -> (m, n) squared Euclidean distances."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    xn = jnp.sum(x * x, axis=1)[:, None]
    cn = jnp.sum(c * c, axis=1)[None, :]
    return xn - 2.0 * (x @ c.T) + cn


@jax.jit
def bid_top2_ref(x: jnp.ndarray, c: jnp.ndarray, prices: jnp.ndarray):
    """Reference for the fused bidding kernel (row constant dropped)."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    vals = -2.0 * (x @ c.T) + jnp.sum(c * c, axis=1)[None, :] - prices[None, :]
    j1 = jnp.argmax(vals, axis=1).astype(jnp.int32)
    v1 = jnp.take_along_axis(vals, j1[:, None], axis=1)[:, 0]
    masked = vals.at[jnp.arange(vals.shape[0]), j1].set(_NEG)
    v2 = jnp.max(masked, axis=1)
    return v1, j1, v2


@jax.jit
def ssm_scan_ref(dt, b_in, c_out, x_in, a_mat):
    """Reference selective scan: dt/x (B, S, di), b/c (B, S, ds), a (di, ds).
    Returns (y (B, S, di), h_final (B, di, ds))."""
    bsz, _seq, di = dt.shape
    ds = a_mat.shape[1]

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        da = jnp.exp(dt_t[:, :, None] * a_mat[None])
        h = h * da + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        return h, jnp.sum(h * c_t[:, None, :], axis=-1)

    xs = tuple(t.transpose(1, 0, 2).astype(jnp.float32)
               for t in (dt, b_in, c_out, x_in))
    h, ys = jax.lax.scan(step, jnp.zeros((bsz, di, ds), jnp.float32), xs)
    return ys.transpose(1, 0, 2), h
