"""Pallas TPU kernel: fused selective-scan chunk (the S`Perf A structural fix).

The chunked jnp scan (mamba.py) still round-trips the SSM state through HBM
once per chunk and leaves the unrolled backward as ~60 small fusions (the
residual 1000s memory term in the falcon train cell).  This kernel computes a
whole chunk of the Mamba recurrence

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t ;   y_t = <h_t, C_t>

with ``h`` resident in VMEM across all C timesteps: HBM traffic per chunk is
exactly inputs + outputs + one state save.  d_inner is the tiled/parallel
grid dim (TP shards it the same way), d_state rides along (16).

Forward-only (serving/prefill use; training integration would add a custom
VJP with the same chunk structure -- documented in EXPERIMENTS.md S`Perf A).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.compat import TPUCompilerParams


def _ssm_chunk_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, h0_ref,
                      y_ref, h_ref, *, chunk):
    h = h0_ref[...]                       # (B, bdi, ds) fp32, stays in VMEM
    a = a_ref[...]                        # (bdi, ds)
    for t in range(chunk):                # unrolled: static small C
        dt_t = dt_ref[t]                  # (B, bdi)
        da = jnp.exp(dt_t[:, :, None] * a[None])
        h = h * da + (dt_t * x_ref[t])[:, :, None] * b_ref[t][:, None, :]
        y_ref[t] = jnp.sum(h * c_ref[t][:, None, :], axis=-1)
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("bdi", "interpret"))
def ssm_scan_chunk_pallas(dt, b_in, c_out, x_in, a_mat, h0, *,
                          bdi: int = 512, interpret: bool = False):
    """One fused chunk of the selective scan.

    dt, x_in: (C, B, di)  fp32;  b_in, c_out: (C, B, ds)  fp32;
    a_mat: (di, ds);  h0: (B, di, ds).
    Returns (y (C, B, di), h_final (B, di, ds)).
    """
    c, bsz, di = dt.shape
    ds = a_mat.shape[1]
    bdi = min(bdi, di)
    assert di % bdi == 0, (di, bdi)
    grid = (di // bdi,)

    y, h = pl.pallas_call(
        functools.partial(_ssm_chunk_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, bsz, bdi), lambda i: (0, 0, i)),
            pl.BlockSpec((c, bsz, ds), lambda i: (0, 0, 0)),
            pl.BlockSpec((c, bsz, ds), lambda i: (0, 0, 0)),
            pl.BlockSpec((c, bsz, bdi), lambda i: (0, 0, i)),
            pl.BlockSpec((bdi, ds), lambda i: (i, 0)),
            pl.BlockSpec((bsz, bdi, ds), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, bsz, bdi), lambda i: (0, 0, i)),
            pl.BlockSpec((bsz, bdi, ds), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, bsz, di), jnp.float32),
            jax.ShapeDtypeStruct((bsz, di, ds), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(dt.astype(jnp.float32), b_in.astype(jnp.float32),
      c_out.astype(jnp.float32), x_in.astype(jnp.float32),
      a_mat.astype(jnp.float32), h0.astype(jnp.float32))
    return y, h


def ssm_scan_pallas(dt, b_in, c_out, x_in, a_mat, *, chunk: int = 16,
                    bdi: int = 512, interpret: bool = False):
    """Full-sequence selective scan via fused chunks.

    dt, x_in: (B, S, di); b_in, c_out: (B, S, ds); a_mat (di, ds).
    Returns (y (B, S, di), h_final (B, di, ds)).
    """
    bsz, seq, di = dt.shape
    ds = a_mat.shape[1]
    chunk = chunk if seq % chunk == 0 else 1

    def to_xs(t):
        t = t.transpose(1, 0, 2)
        return t.reshape(seq // chunk, chunk, bsz, t.shape[-1])

    xs = (to_xs(dt), to_xs(b_in), to_xs(c_out), to_xs(x_in))
    h0 = jnp.zeros((bsz, di, ds), jnp.float32)

    def step(h, inp):
        d_c, b_c, c_c, x_c = inp
        y, h = ssm_scan_chunk_pallas(d_c, b_c, c_c, x_c, a_mat, h,
                                     bdi=min(bdi, di), interpret=interpret)
        return h, y

    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = ys.reshape(seq, bsz, di).transpose(1, 0, 2)
    return y, h_fin
