"""Pallas TPU kernel: fused auction bidding (ABA hot spot #2).

One auction round needs, per unassigned row i, the top-2 of
``value[i, j] = -2 x_i . mu_j + ||mu_j||^2 - price_j`` plus the argmax.  The
naive path materializes the (m, k) value matrix in HBM every round; this
kernel streams column tiles through VMEM and keeps only the running
(v1, j1, v2) per row -- O(m) HBM output instead of O(m*k), turning the
memory-bound bidding step into an MXU-bound one.

The row-constant ``||x_i||^2`` is dropped: v1 - v2 (the bid increment) and the
argmax are invariant to per-row constants.

The streaming core's chunk steps use the gather-fused twin of this kernel
(``repro.kernels.gather.bid_top2_gather_pallas``, dispatched through
``repro.kernels.ops.bid_top2(..., idx=)``): same tile loop and top-2 merge,
but the row block arrives through a double-buffered DMA ring indexed by a
prefetched ``idx`` vector, so the gathered copy never exists in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import TPUCompilerParams

_NEG = -1e30


def _bid_kernel(x_ref, c_ref, cn_ref, p_ref, v1_ref, j1_ref, v2_ref,
                *, bn, n_steps):
    """Grid = (M/bm, K/bn); the column dim j is innermost (sequential merge)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        v1_ref[...] = jnp.full_like(v1_ref, _NEG)
        j1_ref[...] = jnp.zeros_like(j1_ref)
        v2_ref[...] = jnp.full_like(v2_ref, _NEG)

    vals = jax.lax.dot_general(
        x_ref[...], c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    vals = -2.0 * vals + (cn_ref[...] - p_ref[...])[None, :]

    # tile top-2 (iota-based, TPU-safe)
    col = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    t_v1 = jnp.max(vals, axis=1)
    t_j1 = jnp.min(jnp.where(vals >= t_v1[:, None], col, bn), axis=1)
    t_v2 = jnp.max(jnp.where(col == t_j1[:, None], _NEG, vals), axis=1)
    t_j1 = t_j1 + j * bn

    # merge with running top-2: second best of two sorted pairs
    r_v1, r_j1, r_v2 = v1_ref[...], j1_ref[...], v2_ref[...]
    take = t_v1 > r_v1
    new_v1 = jnp.where(take, t_v1, r_v1)
    new_j1 = jnp.where(take, t_j1, r_j1)
    new_v2 = jnp.maximum(jnp.minimum(t_v1, r_v1), jnp.maximum(t_v2, r_v2))
    v1_ref[...] = new_v1
    j1_ref[...] = new_j1
    v2_ref[...] = new_v2


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "interpret"))
def bid_top2_pallas(
    x: jnp.ndarray,
    c: jnp.ndarray,
    prices: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = False,
):
    """(m, d), (k, d), (k,) -> (v1, j1, v2) each (m,).

    v1/v2 are the best/second-best *reduced* values (row constant dropped);
    j1 is the argmax column.  Padded columns get price +inf so they never win.
    """
    m, d = x.shape
    k, d2 = c.shape
    assert d == d2
    bm, bn = min(bm, _rup(m, 8)), min(bn, _rup(k, 128))
    mp, kp = _rup(m, bm), _rup(k, bn)
    xp = jnp.zeros((mp, d), jnp.float32).at[:m].set(x.astype(jnp.float32))
    cp = jnp.zeros((kp, d), jnp.float32).at[:k].set(c.astype(jnp.float32))
    cn = jnp.sum(cp * cp, axis=1)
    pp = jnp.full((kp,), -_NEG, jnp.float32).at[:k].set(prices.astype(jnp.float32))

    v1, j1, v2 = pl.pallas_call(
        functools.partial(_bid_kernel, bn=bn, n_steps=kp // bn),
        grid=(mp // bm, kp // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xp, cp, cn, pp)
    return v1[:m], j1[:m], v2[:m]


def _rup(v: int, m: int) -> int:
    return -(-v // m) * m
