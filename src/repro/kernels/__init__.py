"""Pallas TPU kernels for the compute hot-spots the paper optimizes:
the object<->centroid cost matrix (Fact 1 fast path) and the auction
bidding reduction.  ops.py holds the jit'd public wrappers, ref.py the
pure-jnp oracles used by the allclose tests."""

from repro.kernels.ops import bid_top2, cdist
from repro.kernels.ref import bid_top2_ref, cdist_ref, ssm_scan_ref
from repro.kernels.ssm_scan import ssm_scan_pallas

__all__ = ["bid_top2", "cdist", "bid_top2_ref", "cdist_ref",
           "ssm_scan_ref", "ssm_scan_pallas"]
