"""Pallas TPU kernel: tiled squared-Euclidean cost matrix (ABA hot spot #1).

Computes ``C[i, j] = ||x_i - mu_j||^2 = ||x_i||^2 - 2 x_i . mu_j + ||mu_j||^2``
so the dominant term is a matmul that runs on the MXU.  Blocks are 128-aligned
(MXU native tile) and accumulation is fp32 in VMEM scratch; norms are folded
in on the last reduction step, so the cost matrix is produced in one pass
over HBM with arithmetic intensity ~ bm*bn*D / ((bm+bn)*D) elements.

The ABA scan calls this once per batch with (K, D) x (K, D) -> (K, K); the
hierarchical/vmapped path calls it with a leading group dimension.  The
streaming core's chunk steps use the gather-fused twin
(``repro.kernels.gather.cdist_gather_pallas``, dispatched through
``repro.kernels.ops.cdist(..., idx=)``), whose row blocks stream HBM -> VMEM
through a double-buffered DMA ring instead of reading a pre-gathered copy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams


def _cdist_kernel(x_ref, c_ref, xn_ref, cn_ref, o_ref, acc_ref, *, k_steps):
    """Grid = (M/bm, N/bn, D/bk); k (reduction over D) is the innermost dim."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # (bm, bk) x (bn, bk)^T
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        o_ref[...] = (
            xn_ref[...][:, None] - 2.0 * acc_ref[...] + cn_ref[...][None, :]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"),
)
def cdist_pallas(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """(m, d), (n, d) -> (m, n) squared distances.  Pads to block multiples.

    Leading chunk dims are handled by the ``repro.kernels.ops.cdist``
    dispatcher (it flattens them into ``m``); already-aligned inputs are fed
    straight to the kernel so the streaming path's chunked calls do not pay
    an extra O(m*d) padded copy.
    """
    m, d = x.shape
    n, d2 = c.shape
    assert d == d2, (x.shape, c.shape)
    bm, bn, bk = min(bm, _rup(m, 8)), min(bn, _rup(n, 128)), min(bk, _rup(d, 128))
    mp, np_, dp = _rup(m, bm), _rup(n, bn), _rup(d, bk)
    xp = (x.astype(jnp.float32) if (mp, dp) == (m, d) else
          jnp.zeros((mp, dp), jnp.float32).at[:m, :d].set(
              x.astype(jnp.float32)))
    cp = (c.astype(jnp.float32) if (np_, dp) == (n, d) else
          jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(
              c.astype(jnp.float32)))
    xn = jnp.sum(xp * xp, axis=1)
    cn = jnp.sum(cp * cp, axis=1)
    k_steps = dp // bk

    out = pl.pallas_call(
        functools.partial(_cdist_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, cp, xn, cn)
    return out[:m, :n]


def _rup(v: int, m: int) -> int:
    return -(-v // m) * m
