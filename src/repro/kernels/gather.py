"""Pallas TPU kernels: double-buffered row gather + fused gather-compute.

The streaming ABA core touches its data one chunk at a time through an
index gather (``x[idx_chunk]``).  On TPU a plain gather serializes: HBM row
movement for chunk t+1 waits for chunk t's compute.  These kernels pipeline
it instead -- rows are pulled HBM -> VMEM with explicit ``make_async_copy``
DMAs into a 2-slot scratch ring, so while block ``j`` is being consumed the
copies for block ``j+1`` are already in flight (classic double buffering;
the scalar-prefetch index vector is available to the kernel before the grid
runs, which is what lets it compute source addresses ahead of time).

Three entry points, all sharing the same issue/wait ring:

- :func:`gather_rows_pallas` -- pure gather, ``x[idx]`` with overlapped DMA.
- :func:`bid_top2_gather_pallas` -- fused ``bid_top2(x[idx], c, prices)``:
  the gathered rows never round-trip to HBM; each row block is DMA'd once
  and reduced against every centroid tile while the next block streams in.
- :func:`cdist_gather_pallas` -- fused ``cdist(x[idx], c)`` (untiled D; the
  dispatcher composes gather + tiled cdist instead when D is too large for
  full rows in VMEM).

On CPU these run under ``interpret=True`` for parity tests only -- the
dispatcher (:func:`repro.kernels.ops.gather_rows`) uses the jnp take there,
because interpreting a per-row DMA loop in Python has no fidelity value.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams

_NEG = -1e30


def _issue_block(idx_ref, x_ref, rows, sems, slot, blk, bm):
    """Start the per-row HBM->VMEM copies for row block ``blk`` into ``slot``."""

    def row(r, _):
        src = x_ref.at[idx_ref[blk * bm + r]]
        pltpu.make_async_copy(src, rows.at[slot, r], sems.at[slot, r]).start()
        return 0

    jax.lax.fori_loop(0, bm, row, 0)


def _wait_block(idx_ref, x_ref, rows, sems, slot, blk, bm):
    """Block until every row of ``blk`` has landed in ``slot``."""

    def row(r, _):
        pltpu.make_async_copy(
            x_ref.at[idx_ref[blk * bm + r]], rows.at[slot, r],
            sems.at[slot, r]).wait()
        return 0

    jax.lax.fori_loop(0, bm, row, 0)


# ---------------------------------------------------------------------------
# Pure gather
# ---------------------------------------------------------------------------


def _gather_kernel(idx_ref, x_ref, o_ref, rows, sems, *, bm):
    """Grid = (M/bm,): copy-out slot j%2 while slot (j+1)%2 fills."""
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _prologue():
        _issue_block(idx_ref, x_ref, rows, sems, 0, 0, bm)

    @pl.when(j + 1 < nb)
    def _prefetch():
        _issue_block(idx_ref, x_ref, rows, sems, (j + 1) % 2, j + 1, bm)

    _wait_block(idx_ref, x_ref, rows, sems, j % 2, j, bm)
    o_ref[...] = rows[j % 2]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def gather_rows_pallas(
    x: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    bm: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """``x[idx]`` with double-buffered DMA: (n, d), (m,) -> (m, d) float32.

    Out-of-range indices are clipped (the streaming core clamps sentinels
    itself and masks their values downstream).
    """
    n, d = x.shape
    m = idx.shape[0]
    bm = min(bm, _rup(m, 8))
    mp = _rup(m, bm)
    idx_p = jnp.clip(idx.astype(jnp.int32), 0, n - 1)
    if mp > m:
        idx_p = jnp.concatenate([idx_p, jnp.zeros((mp - m,), jnp.int32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((bm, d), lambda j, idx_ref: (j, 0)),
        scratch_shapes=[pltpu.VMEM((2, bm, d), jnp.float32),
                        pltpu.SemaphoreType.DMA((2, bm))],
    )
    out = pl.pallas_call(
        functools.partial(_gather_kernel, bm=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, d), jnp.float32),
        interpret=interpret,
    )(idx_p, x.astype(jnp.float32))
    return out[:m]


# ---------------------------------------------------------------------------
# Fused gather + bid_top2
# ---------------------------------------------------------------------------


def _bid_gather_kernel(idx_ref, x_ref, c_ref, cn_ref, p_ref,
                       v1_ref, j1_ref, v2_ref, rows, sems, *, bm, bn):
    """Grid = (M/bm, K/bn), j innermost.  Row block i is DMA'd once into the
    2-slot ring at its first column step and reduced against every centroid
    tile; block i+1's copies are issued at the same point, so they overlap
    the whole inner loop over centroid tiles."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _prologue():
        _issue_block(idx_ref, x_ref, rows, sems, 0, 0, bm)

    @pl.when(j == 0)
    def _arrive():
        _wait_block(idx_ref, x_ref, rows, sems, i % 2, i, bm)

        @pl.when(i + 1 < pl.num_programs(0))
        def _prefetch():
            _issue_block(idx_ref, x_ref, rows, sems, (i + 1) % 2, i + 1, bm)

        v1_ref[...] = jnp.full_like(v1_ref, _NEG)
        j1_ref[...] = jnp.zeros_like(j1_ref)
        v2_ref[...] = jnp.full_like(v2_ref, _NEG)

    vals = jax.lax.dot_general(
        rows[i % 2], c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    vals = -2.0 * vals + (cn_ref[...] - p_ref[...])[None, :]

    col = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    t_v1 = jnp.max(vals, axis=1)
    t_j1 = jnp.min(jnp.where(vals >= t_v1[:, None], col, bn), axis=1)
    t_v2 = jnp.max(jnp.where(col == t_j1[:, None], _NEG, vals), axis=1)
    t_j1 = t_j1 + j * bn

    r_v1, r_j1, r_v2 = v1_ref[...], j1_ref[...], v2_ref[...]
    take = t_v1 > r_v1
    v1_ref[...] = jnp.where(take, t_v1, r_v1)
    j1_ref[...] = jnp.where(take, t_j1, r_j1)
    v2_ref[...] = jnp.maximum(jnp.minimum(t_v1, r_v1),
                              jnp.maximum(t_v2, r_v2))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def bid_top2_gather_pallas(
    x: jnp.ndarray,
    idx: jnp.ndarray,
    c: jnp.ndarray,
    prices: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 512,
    interpret: bool = False,
):
    """``bid_top2(x[idx], c, prices)`` without materializing ``x[idx]``:
    (n, d), (m,), (k, d), (k,) -> (v1, j1, v2) each (m,)."""
    n, d = x.shape
    m = idx.shape[0]
    k, d2 = c.shape
    assert d == d2, (x.shape, c.shape)
    bm, bn = min(bm, _rup(m, 8)), min(bn, _rup(k, 128))
    mp, kp = _rup(m, bm), _rup(k, bn)
    idx_p = jnp.clip(idx.astype(jnp.int32), 0, n - 1)
    if mp > m:
        idx_p = jnp.concatenate([idx_p, jnp.zeros((mp - m,), jnp.int32)])
    cp = jnp.zeros((kp, d), jnp.float32).at[:k].set(c.astype(jnp.float32))
    cn = jnp.sum(cp * cp, axis=1)
    pp = jnp.full((kp,), -_NEG, jnp.float32).at[:k].set(
        prices.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // bm, kp // bn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bn, d), lambda i, j, idx_ref: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j, idx_ref: (j,)),
            pl.BlockSpec((bn,), lambda i, j, idx_ref: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j, idx_ref: (i,)),
            pl.BlockSpec((bm,), lambda i, j, idx_ref: (i,)),
            pl.BlockSpec((bm,), lambda i, j, idx_ref: (i,)),
        ],
        scratch_shapes=[pltpu.VMEM((2, bm, d), jnp.float32),
                        pltpu.SemaphoreType.DMA((2, bm))],
    )
    v1, j1, v2 = pl.pallas_call(
        functools.partial(_bid_gather_kernel, bm=bm, bn=bn),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(idx_p, x.astype(jnp.float32), cp, cn, pp)
    return v1[:m], j1[:m], v2[:m]


# ---------------------------------------------------------------------------
# Fused gather + cdist (untiled D)
# ---------------------------------------------------------------------------


def _cdist_gather_kernel(idx_ref, x_ref, c_ref, cn_ref, o_ref, rows, sems,
                         *, bm):
    """Grid = (M/bm, N/bn), j innermost; full rows in VMEM (no D tiling),
    so ``||x_i||^2`` is computed from the landed scratch rows directly."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _prologue():
        _issue_block(idx_ref, x_ref, rows, sems, 0, 0, bm)

    @pl.when(j == 0)
    def _arrive():
        _wait_block(idx_ref, x_ref, rows, sems, i % 2, i, bm)

        @pl.when(i + 1 < pl.num_programs(0))
        def _prefetch():
            _issue_block(idx_ref, x_ref, rows, sems, (i + 1) % 2, i + 1, bm)

    xb = rows[i % 2]
    dots = jax.lax.dot_general(
        xb, c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xn = jnp.sum(xb * xb, axis=1)
    o_ref[...] = (xn[:, None] - 2.0 * dots + cn_ref[...][None, :]
                  ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "interpret", "out_dtype"))
def cdist_gather_pallas(
    x: jnp.ndarray,
    idx: jnp.ndarray,
    c: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """``cdist(x[idx], c)`` without materializing ``x[idx]``:
    (n, d), (m,), (nc, d) -> (m, nc) squared distances."""
    n, d = x.shape
    m = idx.shape[0]
    nc, d2 = c.shape
    assert d == d2, (x.shape, c.shape)
    bm, bn = min(bm, _rup(m, 8)), min(bn, _rup(nc, 128))
    mp, ncp = _rup(m, bm), _rup(nc, bn)
    idx_p = jnp.clip(idx.astype(jnp.int32), 0, n - 1)
    if mp > m:
        idx_p = jnp.concatenate([idx_p, jnp.zeros((mp - m,), jnp.int32)])
    cp = jnp.zeros((ncp, d), jnp.float32).at[:nc].set(c.astype(jnp.float32))
    cn = jnp.sum(cp * cp, axis=1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // bm, ncp // bn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bn, d), lambda i, j, idx_ref: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j, idx_ref: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, idx_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((2, bm, d), jnp.float32),
                        pltpu.SemaphoreType.DMA((2, bm))],
    )
    out = pl.pallas_call(
        functools.partial(_cdist_gather_kernel, bm=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, ncp), out_dtype),
        compiler_params=TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(idx_p, x.astype(jnp.float32), cp, cn)
    return out[:m, :nc]


def _rup(v: int, m: int) -> int:
    return -(-v // m) * m
