"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body in Python -- correct
but slow, so the wrappers fall back to the jnp reference for *large* CPU
inputs while tests pin ``force="pallas"`` to exercise the kernel path.

Both dispatchers accept leading *chunk*/stack dims:

- ``cdist`` takes ``(..., m, d)`` rows against one shared ``(n, d)`` centroid
  set; leading dims are flattened into the row axis (one tiled kernel launch,
  not one per chunk) and restored on the output.  Used by chunked distance
  workloads (e.g. ``benchmarks.kernel_bench``'s chunked row); the streaming
  ABA core's own centrality pass stays on fused elementwise jnp because its
  single-centroid distance is bandwidth-bound either way and the bit-parity
  contract pins its exact arithmetic.
- ``bid_top2`` takes an optional stacked ``(G, m, d) x (G, k, d)`` problem
  batch -- the ABA core's fused path feeds its per-scan-step group stacks
  through this (per-group centroids differ, so it vmaps the kernel; Pallas
  turns the vmap into an extra grid dimension on TPU and the interpret path
  is vmap-safe on CPU).

The interpret-budget rule sees the *total* row count either way, so a big
chunked CPU call still falls back to the jnp reference instead of crawling
through Python-interpreted tiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bid_top2 import bid_top2_pallas
from repro.kernels.cdist import cdist_pallas
from repro.kernels.gather import (bid_top2_gather_pallas, cdist_gather_pallas,
                                  gather_rows_pallas)
from repro.kernels.ref import bid_top2_ref, cdist_ref

_CPU_INTERPRET_BUDGET = 1 << 22  # elements; above this CPU uses the ref
_GATHER_FUSE_MAX_D = 512  # fused-gather kernels keep full rows in VMEM


def _backend() -> str:
    return jax.default_backend()


def resolve_path(m: int, k: int, force: str | None = None) -> str:
    """Which path an (m, k)-sized dispatch takes: 'pallas' (TPU compiled),
    'pallas-interpret' (forced, or CPU under the interpret budget), or 'ref'
    (jnp fallback).  The single copy of the rule: the dispatchers below
    branch on it and benchmarks label their rows with it.  ``m`` is the
    *total* row count (leading chunk dims included).
    """
    if force == "ref":
        return "ref"
    if _backend() == "tpu":
        return "pallas"
    if force == "pallas" or m * k <= _CPU_INTERPRET_BUDGET:
        return "pallas-interpret"
    return "ref"


def gather_path(force: str | None = None) -> str:
    """Which path a row-gather dispatch takes: 'pallas' (TPU compiled DMA
    pipeline), 'pallas-interpret' (forced only), or 'ref' (jnp take).

    Deliberately NOT :func:`resolve_path`: on CPU the default is ALWAYS the
    ref -- interpreting a per-row DMA loop in Python is pure overhead with no
    fidelity value (there is no DMA to overlap), and the streaming core calls
    this inside every chunk step.  Tests pin ``force="pallas"`` to exercise
    the kernel ring under interpret mode.
    """
    if force == "ref":
        return "ref"
    if _backend() == "tpu":
        return "pallas"
    if force == "pallas":
        return "pallas-interpret"
    return "ref"


def gather_rows(x: jnp.ndarray, idx: jnp.ndarray, *,
                force: str | None = None, **block_kw) -> jnp.ndarray:
    """``x[idx]`` as float32: (n, d), (m,) -> (m, d).

    On TPU this is the double-buffered DMA gather
    (:func:`repro.kernels.gather.gather_rows_pallas`) -- the next block's
    HBM row movement overlaps the current block's copy-out; on CPU it is the
    plain jnp take (bit-identical, so the streaming core's parity contract
    is path-independent).  Out-of-range indices are clipped on the kernel
    path; callers clamp before the ref path.
    """
    path = gather_path(force)
    if path == "ref":
        return x[idx].astype(jnp.float32)
    return gather_rows_pallas(x, idx, interpret=path != "pallas", **block_kw)


def cdist(x: jnp.ndarray, c: jnp.ndarray, *, idx: jnp.ndarray | None = None,
          force: str | None = None, **block_kw) -> jnp.ndarray:
    """Squared-distance cost matrix; kernel on TPU, ref fallback on big-CPU.

    ``x`` may carry leading chunk dims: ``(..., m, d) x (n, d) -> (..., m, n)``
    (flattened into one tiled launch against the shared ``c``).

    With ``idx`` the rows are ``x[idx]`` (x must be 2-D): on TPU the fused
    gather-compute kernel streams each row block HBM -> VMEM exactly once via
    the double-buffered DMA ring and never materializes the gathered copy
    (falling back to gather + tiled kernel when d exceeds the full-row VMEM
    budget); elsewhere it is a plain take + the usual dispatch.
    """
    if idx is not None:
        assert x.ndim == 2, "idx gather needs flat (n, d) x"
        path = resolve_path(idx.shape[0], c.shape[0], force)
        if path == "ref" or x.shape[1] > _GATHER_FUSE_MAX_D:
            return cdist(gather_rows(x, idx, force=force), c, force=force,
                         **block_kw)
        return cdist_gather_pallas(x, idx, c, interpret=path != "pallas",
                                   **block_kw)
    lead = x.shape[:-2]
    if lead:
        x = x.reshape(-1, x.shape[-1])
    path = resolve_path(x.shape[0], c.shape[0], force)
    out = (cdist_ref(x, c) if path == "ref"
           else cdist_pallas(x, c, interpret=path != "pallas", **block_kw))
    return out.reshape(*lead, -1, out.shape[-1]) if lead else out


def bid_top2(x: jnp.ndarray, c: jnp.ndarray, prices: jnp.ndarray, *,
             idx: jnp.ndarray | None = None, force: str | None = None,
             **block_kw):
    """Fused auction bidding reduction (v1, j1, v2 per row).

    Accepts a single ``(m, d) x (k, d)`` problem or a stacked
    ``(G, m, d) x (G, k, d)`` batch with ``(G, k)`` prices (each group has
    its own centroid set, so the stack vmaps the kernel).

    With ``idx`` the rows are ``x[idx]`` (x must be flat (n, d)): on TPU the
    fused gather-bid kernel DMAs each row block once through the
    double-buffered ring and reduces it against every centroid tile while
    the next block streams in; elsewhere it is a take + the usual dispatch.
    """
    if idx is not None:
        assert x.ndim == 2, "idx gather needs flat (n, d) x"
        path = resolve_path(idx.shape[0], c.shape[-2], force)
        if path == "ref" or x.shape[1] > _GATHER_FUSE_MAX_D:
            return bid_top2(gather_rows(x, idx, force=force), c, prices,
                            force=force, **block_kw)
        return bid_top2_gather_pallas(x, idx, c, prices,
                                      interpret=path != "pallas", **block_kw)
    if x.ndim == 3:
        total_m = x.shape[0] * x.shape[1]
        path = resolve_path(total_m, c.shape[-2], force)
        if path == "ref":
            return jax.vmap(bid_top2_ref)(x, c, prices)
        return jax.vmap(
            lambda xg, cg, pg: bid_top2_pallas(
                xg, cg, pg, interpret=path != "pallas", **block_kw)
        )(x, c, prices)
    path = resolve_path(x.shape[0], c.shape[0], force)
    if path == "ref":
        return bid_top2_ref(x, c, prices)
    return bid_top2_pallas(x, c, prices, interpret=path != "pallas",
                           **block_kw)
