"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body in Python -- correct
but slow, so the wrappers fall back to the jnp reference for *large* CPU
inputs while tests pin ``force="pallas"`` to exercise the kernel path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bid_top2 import bid_top2_pallas
from repro.kernels.cdist import cdist_pallas
from repro.kernels.ref import bid_top2_ref, cdist_ref

_CPU_INTERPRET_BUDGET = 1 << 22  # elements; above this CPU uses the ref


def _backend() -> str:
    return jax.default_backend()


def cdist(x: jnp.ndarray, c: jnp.ndarray, *, force: str | None = None,
          **block_kw) -> jnp.ndarray:
    """Squared-distance cost matrix; kernel on TPU, ref fallback on big-CPU."""
    if force == "ref":
        return cdist_ref(x, c)
    if force == "pallas" or _backend() == "tpu":
        return cdist_pallas(x, c, interpret=_backend() != "tpu", **block_kw)
    if x.shape[0] * c.shape[0] <= _CPU_INTERPRET_BUDGET:
        return cdist_pallas(x, c, interpret=True, **block_kw)
    return cdist_ref(x, c)


def bid_top2(x: jnp.ndarray, c: jnp.ndarray, prices: jnp.ndarray, *,
             force: str | None = None, **block_kw):
    """Fused auction bidding reduction (v1, j1, v2 per row)."""
    if force == "ref":
        return bid_top2_ref(x, c, prices)
    if force == "pallas" or _backend() == "tpu":
        return bid_top2_pallas(x, c, prices, interpret=_backend() != "tpu",
                               **block_kw)
    if x.shape[0] * c.shape[0] <= _CPU_INTERPRET_BUDGET:
        return bid_top2_pallas(x, c, prices, interpret=True, **block_kw)
    return bid_top2_ref(x, c, prices)
