"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body in Python -- correct
but slow, so the wrappers fall back to the jnp reference for *large* CPU
inputs while tests pin ``force="pallas"`` to exercise the kernel path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bid_top2 import bid_top2_pallas
from repro.kernels.cdist import cdist_pallas
from repro.kernels.ref import bid_top2_ref, cdist_ref

_CPU_INTERPRET_BUDGET = 1 << 22  # elements; above this CPU uses the ref


def _backend() -> str:
    return jax.default_backend()


def resolve_path(m: int, k: int, force: str | None = None) -> str:
    """Which path an (m, k)-sized dispatch takes: 'pallas' (TPU compiled),
    'pallas-interpret' (forced, or CPU under the interpret budget), or 'ref'
    (jnp fallback).  The single copy of the rule: the dispatchers below
    branch on it and benchmarks label their rows with it.
    """
    if force == "ref":
        return "ref"
    if _backend() == "tpu":
        return "pallas"
    if force == "pallas" or m * k <= _CPU_INTERPRET_BUDGET:
        return "pallas-interpret"
    return "ref"


def cdist(x: jnp.ndarray, c: jnp.ndarray, *, force: str | None = None,
          **block_kw) -> jnp.ndarray:
    """Squared-distance cost matrix; kernel on TPU, ref fallback on big-CPU."""
    path = resolve_path(x.shape[0], c.shape[0], force)
    if path == "ref":
        return cdist_ref(x, c)
    return cdist_pallas(x, c, interpret=path != "pallas", **block_kw)


def bid_top2(x: jnp.ndarray, c: jnp.ndarray, prices: jnp.ndarray, *,
             force: str | None = None, **block_kw):
    """Fused auction bidding reduction (v1, j1, v2 per row)."""
    path = resolve_path(x.shape[0], c.shape[0], force)
    if path == "ref":
        return bid_top2_ref(x, c, prices)
    return bid_top2_pallas(x, c, prices, interpret=path != "pallas",
                           **block_kw)
