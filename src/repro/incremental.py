"""Delta updates on live partitions: the incremental anticlustering tier.

Every other entry point re-solves from scratch; real deployments mostly see
*deltas* -- a handful of rows arrive (new samples for the next fold split, a
fresh batch joining a training pool) and a handful depart (consumed, expired,
filtered out).  Re-running the full assignment-based solve for a 1% delta
throws away the other 99% of the answer.  This module keeps a partition
*live*: departures free capacity in their clusters and down-date the carried
centrality moments; arrivals are placed by a small restricted assignment over
only the open capacity, with every other row's label -- and every other
cluster's dual price -- frozen.

How a delta is absorbed
-----------------------
With ``n'`` post-delta rows, the balance constraint allows each of the ``k``
clusters ``floor(n'/k)`` or ``ceil(n'/k)`` rows.  Given the kept rows' label
counts ``sizes_c``, cluster ``c`` exposes ``cap_c = ceil' - sizes_c`` open
*slots*, of which the first ``lo_c = max(0, floor' - sizes_c)`` are
*mandatory* (must be filled or the cluster ends below the floor).

Placing the ``m`` arrivals onto those slots is a transportation problem with
*massively duplicated columns* (every open slot of a cluster is identical),
which is exactly the degenerate regime where a single dense slot-LAP is
slow: tied objects make Jacobi bidders pile onto one slot and prices crawl
in epsilon steps.  So the delta core mirrors the paper's own decomposition
instead.  Arrivals are sorted by centrality against the *carried* global
moments (far first -- this is why :class:`~repro.anticluster.ABAState`
carries ``moment_sum``/``moment_count`` and why departures down-date them),
then split into ``B = max_c cap_c`` batches matched to a rank-indexed slot
schedule: batch ``b`` owns each cluster's rank-``b`` open slot (so a batch
never sees a duplicate column), and mandatory slots land in the earliest
batches by construction.  One *batched* ``(B, k, k)`` LAP -- the same
auction shape ``repro.core.aba`` solves per row-batch, warm-started from
the live partition's per-cluster dual prices -- places everything at once:
batch rows maximize ``||x_i - mu_c||^2`` at the current centroids, dummy
rows are repelled from mandatory slots (and everyone from void slots) by a
span-scaled penalty, and the warm prices engage the auction's adaptive
re-entry probe (`repro.core.assignment`): near-equilibrium clusters re-run
only the final small-epsilon phase, which is what "all other prices frozen"
means operationally -- uncontested clusters never re-bid.
(:func:`repro.core.assignment.solve_restricted_slots` remains the exact
dense-slot primitive for small ``T``; the batched schedule is how the delta
path stays strictly cheaper than a full repartition, its work being
``B/(n/k)`` of the full solve's.)

When the delta is too large for a local patch to be honest -- more than
``spec.update_threshold`` of the post-delta rows, a cluster left above the
new ceiling, too few arrivals to refill the floors, or a restricted problem
bigger than :data:`_MAX_SLOTS` -- ``update`` falls back *loudly* (a
``RuntimeWarning`` naming the reason) to a full warm repartition that is
bit-for-bit identical to calling ``AnticlusterEngine.repartition`` on the
post-delta rows with the carried prices (pinned by
tests/test_incremental.py).

Surfaces
--------
* ``AnticlusterEngine.update(x, state, added=..., removed=...)`` -- the
  engine method (implemented here as :func:`engine_update`); returns
  ``(result, new_x, new_state)`` with ``result.updated`` recording which
  path ran.
* :class:`IncrementalPartition` -- a convenience wrapper owning the running
  ``x`` / labels / :class:`ABAState`, for callers who want a mutable live
  partition instead of threading state by hand (the serving tier's live
  lane, ``repro.data.folds.fold_partition``).
"""

from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.anticluster import (ABAState, AnticlusterEngine, AnticlusterResult,
                               AnticlusterSpec, _certificate, _cluster_prices,
                               _resolve_spec, _result_stats)
from repro.core.aba import delta_moments
from repro.core.assignment import get_solver

__all__ = ["IncrementalPartition"]


def _slot_schedule(sizes_kept: np.ndarray, m: int, floor_new: int,
                   ceil_new: int):
    """Host-side rank-indexed batch schedule for the arriving rows.

    Batch ``b`` owns each cluster's rank-``b`` open slot -- present while
    ``b < cap_c``, *mandatory* (must take a real row) while ``b < lo_c`` --
    so no batch ever sees two slots of the same cluster, and the earliest
    batches carry every floor-restoring slot.  Real rows are front-loaded:
    batch ``b`` gets its mandatory quota first, then the leftover arrivals
    in batch order, so far-first sorted rows land early (the paper's
    extreme-rows-pick-first idiom).

    Returns ``(slot_map (B, k) int32 cluster-or--1, mandatory (B, k) bool,
    idx (B, k) sorted-row index or m for dummies, inv_b (m,), inv_j (m,))``
    with ``idx[inv_b[s], inv_j[s]] == s`` for every sorted row ``s``.
    Feasibility (``cap_c >= 0``, ``sum lo <= m <= sum cap``) is the
    caller's pre-check.
    """
    k = sizes_kept.shape[0]
    cap = ceil_new - sizes_kept
    lo = np.maximum(floor_new - sizes_kept, 0)
    B = max(int(cap.max(initial=0)), 1)
    b_idx = np.arange(B)[:, None]
    open_ = b_idx < cap[None, :]
    slot_map = np.where(open_, np.arange(k)[None, :], -1).astype(np.int32)
    mandatory = b_idx < lo[None, :]
    s_b = open_.sum(axis=1)
    rows_b = mandatory.sum(axis=1)
    leftover = m - int(rows_b.sum())
    for b in range(B):
        take = min(leftover, int(s_b[b] - rows_b[b]))
        rows_b[b] += take
        leftover -= take
    starts = np.concatenate([[0], np.cumsum(rows_b)[:-1]])
    idx = np.full((B, k), m, np.int32)
    inv_b = np.empty((m,), np.int32)
    inv_j = np.empty((m,), np.int32)
    for b in range(B):
        r = int(rows_b[b])
        idx[b, :r] = starts[b] + np.arange(r)
        inv_b[starts[b]:starts[b] + r] = b
        inv_j[starts[b]:starts[b] + r] = np.arange(r)
    return slot_map, mandatory, idx, inv_b, inv_j


@functools.partial(jax.jit, static_argnames=("k", "solver", "config"))
def _delta_assign(x_kept, labels_kept, added, cluster_prices, msum, mcnt,
                  slot_map, mandatory, idx, inv_b, inv_j, *, k, solver,
                  config):
    """Batched frozen-price placement of the arriving rows.

    Solves one ``(B, k, k)`` LAP stack over the :func:`_slot_schedule`
    batches -- the same shape the ABA core solves per row batch, so the
    delta path costs ``B`` batch-LAPs against the full solve's ``n'/k``.
    Returns ``(added_labels (m,), new_cluster_prices (k,), sizes_final
    (k,))``; ``added_labels`` is -1 where a row landed on a void slot
    (never, unless the round-capped auction leaves a tangle -- the caller's
    balance check catches it).  One trace per ``(n_kept, m, B)`` signature;
    steady-state same-sized deltas reuse the cache.
    """
    x_kept = x_kept.astype(jnp.float32)
    added = added.astype(jnp.float32)
    m, d = added.shape
    B = slot_map.shape[0]
    sizes = jax.ops.segment_sum(
        jnp.ones((x_kept.shape[0],), jnp.float32), labels_kept,
        num_segments=k)
    sums = jax.ops.segment_sum(x_kept, labels_kept, num_segments=k)
    mu = sums / jnp.maximum(sizes, 1.0)[:, None]

    # centrality sort against the carried (post-delta) global moments:
    # the most-distant arrivals pick their clusters first, as in the full
    # algorithm's centrality pass
    mean = msum / jnp.maximum(mcnt, 1.0)
    order = jnp.argsort(-jnp.sum((added - mean[None]) ** 2, axis=-1))
    srt = jnp.concatenate([added[order], jnp.zeros((1, d), jnp.float32)])
    rows = srt[idx]                                   # (B, k, d)
    is_dummy = idx == m                               # (B, k) rows
    void = slot_map < 0                               # (B, k) columns
    mu_b = mu[jnp.maximum(slot_map, 0)]               # (B, k, d)
    # maximize ||x - mu||^2; ||x||^2 is a per-row constant and drops,
    # leaving the batch LAP's reduced benefit (repro.core.aba)
    val = (-2.0 * jnp.einsum("bid,bjd->bij", rows, mu_b)
           + jnp.sum(mu_b * mu_b, axis=-1)[:, None, :])
    # span-scaled penalty (NOT aba_core's absolute _MASK_COST, which would
    # inflate the span-derived epsilon schedule): an eps-optimal solution
    # never takes a penalized pair it can avoid, and the baseline dummy/void
    # value 0 is folded into the span
    real = (~is_dummy[:, :, None]) & (~void[:, None, :])
    hi = jnp.maximum(jnp.max(jnp.where(real, val, -jnp.inf)), 0.0)
    lo_v = jnp.minimum(jnp.min(jnp.where(real, val, jnp.inf)), 0.0)
    pen = -(4.0 * jnp.maximum(hi - lo_v, 1e-6) + 1.0)
    val = jnp.where(
        is_dummy[:, :, None],
        jnp.where(mandatory[:, None, :] & ~void[:, None, :], pen, 0.0),
        jnp.where(void[:, None, :], pen, val))
    p0 = jnp.where(void, 0.0,
                   cluster_prices[jnp.maximum(slot_map, 0)])  # (B, k)
    assign, p_out = get_solver(solver).solve(val, config, p0)

    col = assign[inv_b, inv_j]                        # (m,) sorted order
    srt_labels = slot_map[inv_b, col]
    added_labels = jnp.zeros((m,), jnp.int32).at[order].set(srt_labels)
    # fold the final batch duals back to one price per cluster (mean over
    # its open slots); clusters with no open slot keep their frozen price
    seg = jnp.where(void, k, slot_map).reshape(-1)
    p_sum = jax.ops.segment_sum(p_out.reshape(-1), seg,
                                num_segments=k + 1)[:k]
    cnt = jax.ops.segment_sum((~void).reshape(-1).astype(jnp.float32), seg,
                              num_segments=k + 1)[:k]
    new_cp = jnp.where(cnt > 0, p_sum / jnp.maximum(cnt, 1.0),
                       cluster_prices)
    sizes_final = (sizes.astype(jnp.int32)
                   + jnp.zeros((k,), jnp.int32)
                   .at[jnp.maximum(added_labels, 0)]
                   .add(jnp.where(added_labels >= 0, 1, 0)))
    return added_labels, new_cp, sizes_final


def _carried_state(state: ABAState, new_n: int, added_x,
                   removed_x) -> ABAState:
    """The post-delta warm state the fallback hands to ``repartition``.

    Prices are n-independent (one dual per cluster per level), so they
    carry verbatim; the centrality moments are delta-merged *exactly*
    (:func:`repro.core.aba.delta_moments` -- the carried sum/count describe
    the current rows exactly, so add/subtract is not an approximation);
    ``prev_labels`` reset to -1 (they index the pre-delta row order).  The
    bit-for-bit fallback contract is pinned against this construction:
    tests build the same state by hand and compare labels with a direct
    ``repartition`` on the post-delta rows.
    """
    msum, mcnt = delta_moments(state.moment_sum, state.moment_count,
                               added=added_x, removed=removed_x)
    return ABAState(prices=state.prices, moment_sum=msum, moment_count=mcnt,
                    prev_labels=jnp.full((new_n,), -1, jnp.int32))


def engine_update(engine: AnticlusterEngine, x, state: ABAState, *,
                  added=None, removed=None):
    """Implementation of :meth:`AnticlusterEngine.update` (see its doc)."""
    with obs.span("engine/update") as _sp:
        return _engine_update(engine, x, state, _sp,
                              added=added, removed=removed)


def _engine_update(engine: AnticlusterEngine, x, state: ABAState, _sp, *,
                   added=None, removed=None):
    spec = engine.spec
    x = jnp.asarray(x).astype(spec.dtype)
    shape = tuple(x.shape)
    if len(shape) != 2:
        raise NotImplementedError(
            "update() takes a flat (n, d) live partition; stacked (G, M, D) "
            "sessions update one group at a time")
    n, d = shape
    mode, plan, solver, _chunk = engine._routed(shape)
    if mode == "mesh":
        raise NotImplementedError(
            "mesh sessions do not support delta updates yet; repartition "
            "(sharded warm starts make it cheap)")
    if engine._cats is not None:
        raise NotImplementedError(
            "categorical/fairness quotas pin per-stratum balance, which a "
            "local slot patch cannot restore; update() is category-free -- "
            "repartition")
    if engine._vm is not None:
        raise NotImplementedError(
            "spec.valid_mask sessions carry padding rows; drop the padding "
            "and update the unmasked rows instead")
    if not isinstance(state, ABAState):
        raise TypeError(
            f"update() carries ABAState, got {type(state).__name__} (build "
            "states with engine.partition / previous update calls)")

    added_x = None
    if added is not None:
        added_x = jnp.asarray(added).astype(spec.dtype)
        if added_x.ndim != 2 or (added_x.shape[0] and added_x.shape[1] != d):
            raise ValueError(
                f"added must be (m, {d}) to match x, got "
                f"{tuple(added_x.shape)}")
        if added_x.shape[0] == 0:
            added_x = None
    keep = np.ones((n,), bool)
    r = 0
    if removed is not None:
        rem = np.asarray(removed)
        if rem.dtype == np.bool_:
            if rem.shape != (n,):
                raise ValueError(
                    f"a bool removed mask must be ({n},), got {rem.shape}")
            keep = ~rem
            r = int(rem.sum())
        else:
            rem = rem.astype(np.int64).reshape(-1)
            if rem.size:
                if rem.min() < 0 or rem.max() >= n:
                    raise ValueError(
                        f"removed indices must lie in [0, {n}), got range "
                        f"[{rem.min()}, {rem.max()}]")
                if np.unique(rem).size != rem.size:
                    raise ValueError("removed indices must be unique")
                keep[rem] = False
                r = int(rem.size)
    m = 0 if added_x is None else int(added_x.shape[0])
    _sp.set(n=n, added=m, removed=r, fallback=False)

    if m == 0 and r == 0:
        # zero delta IS a repartition (pinned bit-for-bit by tests)
        res, new_state = engine.repartition(x, state)
        return res, x, new_state

    new_n = n - r + m
    if new_n < spec.k:
        raise ValueError(
            f"the delta leaves n={new_n} rows, fewer than k={spec.k}")

    removed_x = (None if r == 0
                 else x[jnp.asarray(np.flatnonzero(~keep))])
    x_kept = x if r == 0 else x[jnp.asarray(np.flatnonzero(keep))]
    new_x = x_kept if m == 0 else jnp.concatenate([x_kept, added_x])

    def _fallback(reason: str):
        _sp.set(fallback=True, reason=reason)
        warnings.warn(
            f"update(added={m}, removed={r}) on n={n}: {reason}; falling "
            "back to a full warm repartition of the post-delta rows "
            "(bit-for-bit identical to repartition() with the carried "
            "prices)", RuntimeWarning, stacklevel=4)
        res, st = engine.repartition(
            new_x, _carried_state(state, new_n, added_x, removed_x))
        return res, new_x, st

    frac = (m + r) / new_n
    if frac > spec.update_threshold:
        return _fallback(
            f"delta fraction {frac:.3f} exceeds "
            f"update_threshold={spec.update_threshold}")

    prev = np.asarray(state.prev_labels)
    if prev.shape != (n,) or (prev < 0).any() or (prev >= spec.k).any():
        raise ValueError(
            "state carries no labels for these rows (prev_labels unset or "
            "from a different shape); run partition()/repartition() first")

    k = spec.k
    floor_new, ceil_new = new_n // k, -(-new_n // k)
    sizes_kept = np.bincount(prev[keep], minlength=k)
    if sizes_kept.max(initial=0) > ceil_new:
        return _fallback(
            "a cluster exceeds the new size ceiling after the departures "
            "(balance cannot be restored locally)")
    if int(np.maximum(floor_new - sizes_kept, 0).sum()) > m:
        return _fallback(
            "too few arrivals to refill every cluster to the new floor "
            "(balance cannot be restored locally)")

    labels_kept = jnp.asarray(prev[keep].astype(np.int32))
    cp = _cluster_prices(tuple(state.prices), mode)  # (k,) global duals
    msum, mcnt = delta_moments(state.moment_sum, state.moment_count,
                               added=added_x, removed=removed_x)
    if m == 0:
        # departures only: every kept row keeps its label, duals untouched
        # (the feasibility checks above guarantee balance already holds)
        new_labels, new_cp = labels_kept, cp
    else:
        slot_map, mandatory, idx, inv_b, inv_j = _slot_schedule(
            sizes_kept, m, floor_new, ceil_new)
        added_labels, new_cp, sizes_final = _delta_assign(
            x_kept, labels_kept, added_x, cp, msum, mcnt,
            jnp.asarray(slot_map), jnp.asarray(mandatory),
            jnp.asarray(idx), jnp.asarray(inv_b), jnp.asarray(inv_j),
            k=k, solver=solver, config=spec.auction_config)
        labels_np = np.asarray(added_labels)
        sizes_np = np.asarray(sizes_final)
        if (labels_np < 0).any() or sizes_np.min() < floor_new \
                or sizes_np.max() > ceil_new:
            # the round-capped auction can (rarely) leave a row or dummy on
            # the wrong slot; a local patch that breaks balance is worthless
            return _fallback(
                "the restricted assignment could not restore balance "
                "locally")
        new_labels = jnp.concatenate([labels_kept, added_labels])

    # scatter the per-cluster duals back into the state's per-level layout:
    # only the last level's prices index global clusters (labels compose as
    # g * k_last + sub); earlier levels carry over and stay re-centered
    last_shape = state.prices[-1].shape
    new_last = new_cp.reshape(last_shape)
    new_last = new_last - jnp.max(new_last, axis=-1, keepdims=True)
    new_prices = tuple(state.prices[:-1]) + (new_last,)
    new_state = ABAState(prices=new_prices, moment_sum=msum,
                         moment_count=mcnt, prev_labels=new_labels)

    # host-level result statistics, outside the solve (see repartition)
    new_labels = jax.block_until_ready(new_labels)
    sizes, sd, rng = _result_stats(new_x, new_labels, k, None,
                                   diversity=spec.stats)
    bound, gap = (None, None)
    if spec.stats:
        bound, gap = _certificate(new_x, new_labels, new_prices, mode, k,
                                  None)
    result = AnticlusterResult(
        labels=new_labels, cluster_sizes=sizes, diversity_sd=sd,
        diversity_range=rng, k=k, plan=plan, solver=solver,
        variant=spec.variant, dual_bound=bound, gap=gap, updated=True)
    return result, new_x, new_state


class IncrementalPartition:
    """A live partition: owns the running rows/labels/state, absorbs deltas.

    The object-level face of the delta subsystem: construct it with the
    initial rows (solved immediately), then :meth:`update` mutates the
    partition in place as rows arrive and depart.  ``x`` row order after an
    update is ``concat(kept rows in original order, added rows)``.

        live = IncrementalPartition(x0, k=16)
        live.update(added=fresh_rows)            # restricted warm placement
        live.update(removed=np.arange(8))        # departures free capacity
        live.result.gap                          # certificate still attached

    Pass a spec / overrides (a private engine is built) or share an
    ``engine=`` across partitions (one compile cache).  The wrapper adds no
    solver behavior of its own -- everything is
    :meth:`AnticlusterEngine.update` semantics, including the loud
    over-threshold fallback (``result.updated`` False for that call).
    """

    def __init__(self, x, spec: AnticlusterSpec | None = None, *,
                 engine: AnticlusterEngine | None = None, **overrides):
        if engine is not None:
            if spec is not None or overrides:
                raise ValueError(
                    "pass spec/overrides or a prebuilt engine, not both")
            self.engine = engine
        else:
            self.engine = AnticlusterEngine(_resolve_spec(spec, overrides))
        self._x = jnp.asarray(x).astype(self.engine.spec.dtype)
        self.result, self.state = self.engine.partition(self._x)

    @property
    def x(self):
        """The current (n, d) rows, post-delta row order."""
        return self._x

    @property
    def labels(self):
        return self.result.labels

    @property
    def k(self) -> int:
        return self.engine.spec.k

    @property
    def n(self) -> int:
        return int(self._x.shape[0])

    def __len__(self) -> int:
        return self.n

    def update(self, added=None, removed=None) -> AnticlusterResult:
        """Absorb a delta in place; returns (and stores) the new result."""
        result, self._x, self.state = self.engine.update(
            self._x, self.state, added=added, removed=removed)
        self.result = result
        return result

    def repartition(self) -> AnticlusterResult:
        """Force a full warm re-solve of the current rows."""
        self.result, self.state = self.engine.repartition(self._x,
                                                          self.state)
        return self.result
