"""End-to-end observability: trace an engine session, a serving router, and
a training pipeline through ``repro.obs``, then summarize the JSONL.

One scoped ``obs.tracing(...)`` block covers all three tiers:

* an :class:`AnticlusterEngine` built with ``telemetry=True`` -- the solver's
  compiled-path stats pytree (auction rounds per eps phase, warm re-entry)
  surfaces as ``engine.last_telemetry`` and per-phase ``solver/phase`` trace
  events under the ``engine/repartition`` span;
* an :class:`AnticlusterRouter` (inline-driven, ``background=False``) --
  admission, queue-wait, and lane-solve instrumentation, plus the latency /
  queue-wait percentiles on ``ServiceMetrics``;
* an :class:`ABAPipeline` -- dispatch / wait / epoch spans showing how much
  of each solve the overlapped epochs actually hid.

    PYTHONPATH=src python examples/trace_anticluster.py

Writes ``TRACE_smoke.jsonl`` (CI uploads it next to the BENCH artifacts) and
prints the ``tools/trace_report.py`` summary table.
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import obs
from repro.anticluster import AnticlusterEngine, AnticlusterSpec
from repro.serve import AnticlusterRouter
from repro.train.pipeline import ABAPipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="TRACE_smoke.jsonl")
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    with obs.tracing(args.out) as trace:
        # -- engine tier: solver telemetry rides the compiled output -------
        engine = AnticlusterEngine(
            AnticlusterSpec(k=5, solver="auction", telemetry=True))
        x = rng.normal(size=(200, 8)).astype(np.float32)
        res, state = engine.partition(x)
        engine.repartition(x, state)
        tele = obs.summarize_auction_telemetry(engine.last_telemetry)
        print(f"engine: compile_count={engine.compile_count} "
              f"rounds_total={tele['rounds_total']} "
              f"warm_fraction={tele['warm_fraction']:.2f}")

        # -- serving tier: inline-driven router (deterministic, no thread) -
        with AnticlusterRouter(k=5, plan=None, max_group=8,
                               background=False) as router:
            tickets = [router.submit(
                rng.normal(size=(100 + 4 * (i % 3), 8)).astype(np.float32))
                for i in range(6)]
            router.drain()
            for t in tickets:
                assert t.result().balanced
            m = router.metrics()
            print(f"router: completed={m.completed} "
                  f"latency_p50={m.latency_p50 * 1e3:.1f}ms "
                  f"queue_wait_p99={m.queue_wait_p99 * 1e3:.1f}ms")

        # -- training tier: overlapped epoch pipeline ----------------------
        embed = rng.normal(size=(240, 8)).astype(np.float32)
        pipe = ABAPipeline(embed, batch_size=48, seed=0)
        drift = [embed + 0.05 * e for e in range(3)]
        for ep in pipe.epochs(3, features=lambda e: drift[e]):
            for _ in ep:              # "training": just walk the schedule
                pass
        print(f"pipeline: epochs=3 overlapped={pipe.overlapped} "
              f"compile_count={pipe.engine.compile_count}")

    names = {ev["name"] for ev in trace.snapshot()}
    for required in ("engine/repartition", "solver/phase", "serve/admit",
                     "serve/queue_wait", "serve/solve", "pipeline/dispatch",
                     "pipeline/wait", "pipeline/epoch"):
        assert required in names, f"missing span/event {required!r}: {names}"
    assert not obs.enabled(), "tracing() must restore the disabled state"

    print(f"\nwrote {len(trace.snapshot())} events -> {args.out}\n")
    sys.path.insert(0, "tools")
    import trace_report
    print(trace_report.render(trace_report.summarize(
        trace_report.load_events(args.out))))
    print("\nOK")


if __name__ == "__main__":
    main()
