"""End-to-end driver: train an LM with ABA-diverse mini-batches vs random
shuffling (the paper's SGD application, Section 1) and compare convergence.

Runs the ~100M-class smollm-360m family at reduced width for CPU; pass
--full-model to train the real 360M config (hours on this container, the
config itself is the assigned architecture).

    PYTHONPATH=src python examples/minibatch_training.py --steps 120
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full-model", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    base = ["--arch", "smollm-360m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--n-docs", "1024",
            "--log-every", "20"]
    if not args.full_model:
        base += ["--reduced"]
    if args.grad_compression:
        base += ["--grad-compression"]

    print("=== ABA diverse mini-batches ===")
    loss_aba = train_main(base + ["--aba-batching"])
    print("\n=== random shuffling baseline ===")
    loss_rand = train_main(base)
    print(f"\nfinal loss: ABA batches {loss_aba:.4f} "
          f"vs random {loss_rand:.4f}")


if __name__ == "__main__":
    main()
