"""Engine reuse across a 3-epoch mini-batch training loop.

The paper's headline ML workload: every training epoch wants a fresh
diverse mini-batch partition of the (drifting) example embeddings.  The
one-shot ``anticluster()`` pays a cold epsilon-scaling solve per epoch; the
session API compiles once and warm-starts every later epoch from the
carried ``ABAState`` (auction dual prices per level, centrality moments,
previous labels):

    PYTHONPATH=src python examples/epoch_reuse.py

Expect: compile_count stays at 1 across all epochs, warm epochs are faster
than the cold epoch-0 solve, and every epoch's batches remain an exact
balanced partition.
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.data.minibatch import ABABatchSequencer

N, D, BATCH = 4096, 8, 256
EPOCHS = 3


def main():
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(N, D)).astype(np.float32)

    t0 = time.time()
    seq = ABABatchSequencer(feats, BATCH, chunk_size=None)
    t_cold = time.time() - t0
    k = len(seq)
    print(f"sequencer: N={N} D={D} batch={BATCH} -> K={k} mini-batches "
          f"(cold partition + compile {t_cold:.2f}s)")
    sd0, rng0 = seq.diversity_stats()
    print(f"epoch 0 diversity sd={sd0:.3f} range={rng0:.3f} "
          f"plan={'x'.join(map(str, seq.result.plan))}")

    for epoch in range(1, EPOCHS):
        # simulate encoder drift: embeddings move a little every epoch
        feats = feats + rng.normal(size=feats.shape).astype(np.float32) * 0.05
        t0 = time.time()
        n_batches, n_rows = 0, 0
        for batch_idx in seq.epoch(epoch, features=feats):
            n_batches += 1
            n_rows += len(batch_idx)
        t_warm = time.time() - t0
        flat = np.sort(np.concatenate([b for b in seq.batches]))
        assert (flat == np.arange(seq.n_used)).all(), "not a partition!"
        print(f"epoch {epoch}: {n_batches} batches / {n_rows} rows "
              f"re-partitioned warm in {t_warm:.3f}s "
              f"(balanced={seq.result.balanced})")

    assert seq.engine.compile_count == 1, (
        f"engine retraced: compile_count={seq.engine.compile_count}")
    print(f"\ncompile_count={seq.engine.compile_count} after {EPOCHS} epochs "
          "-- one trace, every epoch after the first warm-started")


if __name__ == "__main__":
    main()
