"""Train a tiny registry model through the ABA training pipeline.

The direct-API twin of ``examples/minibatch_training.py`` (which drives the
full ``repro.launch.train`` launcher): this one consumes
:class:`repro.train.pipeline.ABAPipeline`'s epoch iterator by hand, the way
a custom training loop would --

  * the constructor anticlusters the doc embeddings once (one compile);
  * each epoch hands out diverse mini-batches in a deterministic order;
  * with ``features=`` the next epoch's re-partition is dispatched
    asynchronously and drains while the current epoch trains.

    PYTHONPATH=src python examples/train_anticlustered.py

Runs in well under a minute on CPU (CI executes it as an examples-smoke).
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synthetic import lm_token_stream
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.launch.mesh import make_host_mesh
from repro.train import ABAPipeline
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.train_step import make_train_step

N_DOCS, BATCH, SEQ, EPOCHS = 1024, 32, 16, 3


def main():
    cfg = get_config("smollm-360m", reduced=True)
    mesh = make_host_mesh(1, 1)
    tokens, feats = lm_token_stream(N_DOCS, SEQ, cfg.vocab_size, seed=0)

    pipe = ABAPipeline(feats, BATCH, seed=0)
    sd, rg = pipe.diversity_stats(feats)
    print(f"K={len(pipe)} diverse batches  (per-batch diversity sd={sd:.4f}, "
          f"range={rg:.4f})")

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, mesh, OptConfig(lr=3e-3, warmup_steps=5,
                             decay_steps=len(pipe) * EPOCHS),
        loss_chunk=SEQ))

    # features(e) stands in for a drifting encoder embedding; each next
    # epoch's warm re-partition is dispatched before this epoch's steps run
    def drifted(e):
        r = np.random.default_rng(1000 + e)
        return (feats + 0.02 * e * r.normal(size=feats.shape)
                ).astype(np.float32)

    losses = []
    for ep in pipe.epochs(EPOCHS, features=drifted):
        t0 = time.time()
        epoch_losses = []
        for idx in ep:
            batch = {"tokens": jnp.asarray(tokens[idx])}
            params, opt, m = step(params, opt, batch)
            epoch_losses.append(m["loss"])       # no sync inside the epoch
        losses.append(float(epoch_losses[-1]))   # one coalesced sync
        print(f"epoch {ep.index}: last-step loss {losses[-1]:.4f} "
              f"({time.time() - t0:.1f}s, {len(ep)} steps)")
    assert pipe.engine.compile_count == 1, "epochs must reuse one executable"
    assert losses[-1] < losses[0], "training should reduce the loss"
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
