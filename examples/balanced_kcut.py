"""Balanced k-cut partitioning of tabular data with ABA (paper Section 5.5):
minimizing the cut on the complete sq-Euclidean graph == maximizing W(C).

    PYTHONPATH=src python examples/balanced_kcut.py
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.anticluster import anticluster
from repro.core import cut_cost, objective_pairwise
from repro.core.baselines import greedy_kcut, random_partition
from repro.data import synthetic


def main():
    x = synthetic.load("electric")  # N=10000, D=12
    xj = jnp.asarray(x)
    for k in (10, 30):
        rows = []
        for name, fn in [
            ("ABA", lambda: np.asarray(anticluster(xj, k=k, plan=None,
                                       stats=False).labels)),
            ("greedy k-cut (METIS proxy)", lambda: greedy_kcut(x, k)),
            ("random", lambda: random_partition(len(x), k)),
        ]:
            t0 = time.time()
            labels = fn()
            dt = time.time() - t0
            cut = float(cut_cost(xj, jnp.asarray(labels), k))
            w = float(objective_pairwise(xj, jnp.asarray(labels), k))
            sizes = np.bincount(labels, minlength=k)
            rows.append((name, cut, w, dt, sizes.min(), sizes.max()))
        best = min(r[1] for r in rows)
        print(f"\nK={k}")
        for name, cut, w, dt, lo, hi in rows:
            print(f"  {name:28s} cut={cut:15.1f} (+{(cut-best)/best*100:6.3f}%)"
                  f"  W(C)={w:15.1f}  {dt:6.2f}s  sizes {lo}..{hi}")


if __name__ == "__main__":
    main()
