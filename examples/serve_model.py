"""Batched serving example: prefill + jit'd decode steps with a KV cache
(the decode_32k dry-run cell at container scale).

    PYTHONPATH=src python examples/serve_model.py --arch gemma2-2b
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.models.registry import get_config
from repro.models import transformer as T
from repro.serve.generate import Generator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    gen = Generator(cfg, params, max_len=64)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(args.batch, 8)).astype(np.int32)

    t0 = time.time()
    out = gen.generate(prompts, args.steps, temperature=0.8, seed=42)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} steps={args.steps} "
          f"({args.batch * args.steps / dt:.1f} tok/s incl. compile)")
    for i, row in enumerate(out):
        print(f"  request {i}: {row[:12].tolist()}...")


if __name__ == "__main__":
    main()
