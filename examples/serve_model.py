"""Async anticlustering serving: submit/ticket API, continuous batching,
deadlines, and the metrics snapshot.

A mock inference tier: every arriving batch of user feature vectors must be
split into k balanced, maximally-diverse groups (the paper's minibatch
workload) under a latency deadline.  Requests go to an
:class:`AnticlusterRouter` which batches whatever is pending into one
stacked solve -- near-shapes (here 100-120 rows) share one compiled lane
via row-bucket padding, so the 12-request trickle below compiles a couple
of executables, not twelve.

    PYTHONPATH=src python examples/serve_model.py
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.serve import AnticlusterRouter, Rejected


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--k", type=int, default=5)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    sizes = [100, 104, 112, 120]

    with AnticlusterRouter(k=args.k, plan=None, max_group=8) as router:
        # async surface: fire the whole trickle, then collect tickets
        t0 = time.time()
        tickets = []
        for i in range(args.requests):
            x = rng.normal(size=(sizes[i % 4], 8)).astype(np.float32)
            tickets.append(router.submit(x, deadline=30.0))
        for i, t in enumerate(tickets):
            try:
                res = t.result()
                print(f"  request {i:2d}: n={res.labels.shape[0]:3d} "
                      f"sizes={np.asarray(res.cluster_sizes).tolist()} "
                      f"latency={t.latency * 1e3:7.1f} ms")
            except Rejected as e:
                print(f"  request {i:2d}: rejected ({e.reason})")
        dt = time.time() - t0

        # sync surface (the old service API) rides on the same router
        res = router.partition(rng.normal(size=(110, 8)).astype(np.float32))
        assert res.balanced

        m = router.metrics()
        print(f"served {m.completed} requests in {dt:.2f}s "
              f"(incl. compile) on {router.lane_count} lanes")
        print(f"  stacked_calls={m.stacked_calls} solo_calls={m.solo_calls} "
              f"warm_hit_rate={m.warm_hit_rate:.2f}")
        print(f"  stack_occupancy={m.stack_occupancy:.2f} "
              f"row_occupancy={m.row_occupancy:.2f} "
              f"shed_rate={m.shed_rate:.2f}")
        print(f"  lane compile counts: {m.lane_compile_counts}")


if __name__ == "__main__":
    main()
