"""Demonstrates the CPU-jax hang that anticluster()'s blocks-on-labels
guard prevents (NOT part of CI -- this script hangs by design without
the guard).

    PYTHONPATH=src python examples/scipy_deadlock_repro.py          # safe
    PYTHONPATH=src python examples/scipy_deadlock_repro.py --hang   # hangs

Background.  The "scipy" registry solver runs the Hungarian oracle on the
host through ``jax.pure_callback``.  On the CPU backend, dispatching NEW
work while a callback computation is still in flight can deadlock the
runtime: the in-flight computation holds the execution stream waiting for
the host callback to finish, and the fresh dispatch queues behind it on a
thread pool the callback itself needs.  ``anticluster()`` therefore calls
``jax.block_until_ready(labels)`` BEFORE dispatching the result-statistics
ops (see the guard in src/repro/anticluster.py; pinned by
tests/test_anticluster.py::test_scipy_solver_stats_no_deadlock).

This script reproduces both sides:

* default: the shipped (guarded) path -- solve + stats complete;
* ``--hang``: re-enacts the unguarded ordering -- it launches the callback
  solve and immediately dispatches dependent statistics work without
  syncing, inside a watchdog.  If the process would hang, the watchdog
  reports the deadlock and force-exits instead of wedging your terminal.

The hang is timing/backend dependent (it is a scheduling race): on some
machines the unguarded ordering happens to survive.  A clean run of
``--hang`` is NOT proof the guard is unnecessary -- the guarded ordering
is the only one with a completion guarantee.
"""

import argparse
import faulthandler
import os
import sys
import threading
import time

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.anticluster import anticluster

N, D, K = 150, 4, 6
WATCHDOG_S = 30.0


def _data():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))


def run_guarded():
    """The shipped path: anticluster() syncs labels before the stats ops."""
    t0 = time.time()
    res = anticluster(_data(), k=K, plan=None, solver="scipy", stats=True)
    print(f"guarded path OK in {time.time() - t0:.2f}s: "
          f"balanced={res.balanced} diversity_sd={float(res.diversity_sd):.4f}")


def run_unguarded():
    """Re-enact the pre-guard ordering under a watchdog.

    Mirrors what anticluster() used to do: kick off the callback-backed
    label solve, then dispatch the dependent statistics computation while
    the callback may still be in flight (no block_until_ready between).
    """
    done = threading.Event()

    def watchdog():
        if not done.wait(WATCHDOG_S):
            print(f"\nDEADLOCK: no progress after {WATCHDOG_S:.0f}s -- this "
                  "is the hang the blocks-on-labels guard prevents.",
                  flush=True)
            faulthandler.dump_traceback()  # where every thread is stuck
            os._exit(2)  # the runtime is wedged; a clean exit won't happen

    threading.Thread(target=watchdog, daemon=True).start()

    from repro.core.aba import aba_core
    from repro.core.objective import diversity_per_cluster

    x = _data()
    labels = aba_core(x[None], K, solver="scipy")[0]  # callback in flight
    div = diversity_per_cluster(x, labels, K)   # dispatched WITHOUT syncing
    sd = float(jnp.std(div))                    # forces both computations
    done.set()
    print(f"unguarded ordering survived on this machine (scheduling race; "
          f"diversity_sd={sd:.4f}) -- the guard is still required, see "
          "the module docstring")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--hang", action="store_true",
                    help="re-enact the unguarded ordering (may deadlock; "
                         "a watchdog force-exits after "
                         f"{WATCHDOG_S:.0f}s)")
    args = ap.parse_args()
    print(f"backend={jax.default_backend()} devices={jax.device_count()}")
    if args.hang:
        run_unguarded()
    else:
        run_guarded()
