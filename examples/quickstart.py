"""Quickstart: the `anticluster()` front door end to end.

One spec-driven entry point covers every regime -- flat, interleave,
categorical (stratified), hierarchical, and custom LAP solvers from the
registry -- and returns an `AnticlusterResult` with labels, the resolved
plan, per-cluster sizes, and diversity statistics.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.anticluster import (AnticlusterSpec, anticluster,
                               available_solvers)
from repro.core import objective_centroid, objective_pairwise
from repro.core.baselines import fast_anticlustering, random_partition
from repro.data import synthetic


def describe(name, xj, res):
    k = res.k
    ofv = float(objective_centroid(xj, res.labels, k))
    w = float(objective_pairwise(xj, res.labels, k))
    sizes = np.asarray(res.cluster_sizes)
    print(f"{name:26s} plan={'x'.join(map(str, res.plan)):9s} "
          f"ofv={ofv:12.2f}  W(C)={w:14.1f}  "
          f"diversity sd={float(res.diversity_sd):8.3f} "
          f"range={float(res.diversity_range):8.3f}  "
          f"sizes {sizes.min()}..{sizes.max()} balanced={res.balanced}")
    return ofv


def main():
    # a Table-2-style dataset (travel: N=5454, D=24)
    x = synthetic.load("travel")
    xj = jnp.asarray(x)
    n, k = len(x), 10

    print(f"dataset: travel  N={n} D={x.shape[1]}  K={k}")
    print(f"registered LAP solvers: {', '.join(available_solvers())}\n")

    # one spec, varied one field at a time
    base = AnticlusterSpec(k=k)
    for name, spec in [
        ("ABA (auction LAP)", base),
        ("ABA interleave", base.replace(variant="interleave")),
        ("ABA fused-kernel solver", base.replace(solver="auction_fused")),
        ("hierarchical 2x5", base.replace(plan=(2, 5))),
    ]:
        describe(name, xj, anticluster(xj, spec))

    # baselines for scale
    for name, labels in [
        ("exchange P-R5", fast_anticlustering(x, k, n_partners=5)),
        ("random", random_partition(n, k)),
    ]:
        lj = jnp.asarray(labels)
        print(f"{name:26s} {'':14s} "
              f"ofv={float(objective_centroid(xj, lj, k)):12.2f}  "
              f"W(C)={float(objective_pairwise(xj, lj, k)):14.1f}")

    # stratified: categories are balanced exactly across anticlusters (4.3)
    cats = (np.asarray(x)[:, 0] > np.median(np.asarray(x)[:, 0])).astype(np.int32)
    res = anticluster(xj, base.replace(categories=cats))
    per = np.stack([np.bincount(np.asarray(res.labels)[cats == g],
                                minlength=k) for g in range(2)])
    print(f"\nstratified K={k}: per-category per-cluster counts stay within "
          f"one of each other -> spread {per.max(1) - per.min(1)}")

    # very large K via the auto plan (paper Table 5 behaviour): the spec
    # front door resolves the hierarchy -- no separate entry point needed
    res = anticluster(xj, AnticlusterSpec(k=505, max_k=101))
    sizes = np.asarray(res.cluster_sizes)
    print(f"\nK=505 auto plan -> {'x'.join(map(str, res.plan))}: "
          f"sizes {sizes.min()}..{sizes.max()}, balanced={res.balanced}, "
          f"ofv={float(objective_centroid(xj, res.labels, 505)):.2f}")


if __name__ == "__main__":
    main()
