"""Quickstart: anticluster a dataset, inspect quality, and compare variants.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.core import (aba, aba_auto, diversity_stats, hierarchical_aba,
                        objective_centroid, objective_pairwise)
from repro.core.baselines import fast_anticlustering, random_partition
from repro.data import synthetic


def main():
    # a Table-2-style dataset (travel: N=5454, D=24)
    x = synthetic.load("travel")
    xj = jnp.asarray(x)
    n, k = len(x), 10

    print(f"dataset: travel  N={n} D={x.shape[1]}  K={k}\n")
    for name, labels in [
        ("ABA (auction LAP)", np.asarray(aba(xj, k))),
        ("ABA interleave", np.asarray(aba(xj, k, variant="interleave"))),
        ("hierarchical 2x5", np.asarray(hierarchical_aba(xj, (2, 5)))),
        ("exchange P-R5", fast_anticlustering(x, k, n_partners=5)),
        ("random", random_partition(n, k)),
    ]:
        ofv = float(objective_centroid(xj, jnp.asarray(labels), k))
        w = float(objective_pairwise(xj, jnp.asarray(labels), k))
        sd, rg = (float(v) for v in diversity_stats(xj, jnp.asarray(labels), k))
        sizes = np.bincount(labels, minlength=k)
        print(f"{name:20s} ofv={ofv:12.2f}  W(C)={w:14.1f}  "
              f"diversity sd={sd:8.3f} range={rg:8.3f}  "
              f"sizes {sizes.min()}..{sizes.max()}")

    # very large K via the auto plan (paper Table 5 behaviour)
    labels = np.asarray(aba_auto(xj, 505))
    print(f"\nK=505 via auto hierarchical plan: sizes "
          f"{np.bincount(labels).min()}..{np.bincount(labels).max()}, "
          f"ofv={float(objective_centroid(xj, jnp.asarray(labels), 505)):.2f}")


if __name__ == "__main__":
    main()
