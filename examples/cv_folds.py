"""Representative, stratified K-fold cross-validation via ABA (paper
Section 1 / Papenberg & Klau's CV use-case): folds mirror the full data
distribution so validation scores have lower variance than random folds.

    PYTHONPATH=src python examples/cv_folds.py
"""

import sys

sys.path.insert(0, ".")  # benchmarks.common (run from the repo root)
sys.path.insert(0, "src")

import numpy as np

from repro.data.folds import aba_folds, fold_splits
from repro.data import synthetic
from benchmarks.common import kmeans_labels


def main():
    x = synthetic.load("frogs")  # N=7195, D=22
    y = kmeans_labels(x[:, :4], 4)  # stand-in class labels
    n_folds = 5

    for name, labels in [
        ("ABA folds (stratified)", aba_folds(x, n_folds, categories=y)),
        ("random folds", np.random.default_rng(0).integers(0, n_folds,
                                                           len(x))),
    ]:
        # fold representativeness: per-fold feature-mean distance to global
        mu = x.mean(0)
        dists, class_dev = [], []
        for f in range(n_folds):
            xf = x[labels == f]
            dists.append(np.linalg.norm(xf.mean(0) - mu))
            frac = np.bincount(y[labels == f], minlength=4) / len(xf)
            class_dev.append(np.abs(frac - np.bincount(y) / len(y)).max())
        print(f"{name:24s} mean |fold_mu - mu| = {np.mean(dists):.4f}   "
              f"max class-fraction dev = {np.max(class_dev):.4f}")

    labels = aba_folds(x, n_folds, categories=y)
    for i, (tr, va) in enumerate(fold_splits(labels, n_folds)):
        print(f"fold {i}: train {len(tr)}, val {len(va)}")


if __name__ == "__main__":
    main()
